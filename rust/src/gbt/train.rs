//! Oblivious-GBT training: second-order gradient boosting with
//! histogram-binned split search, level-shared splits, shrinkage and L2
//! regularization — the from-scratch xgboost substitute.
//!
//! Squared-error objective: gradients `g_i = pred_i - y_i`, hessians
//! `h_i = 1`.  At every tree level the single (feature, threshold) pair
//! maximizing the summed split gain across all current leaves is chosen
//! (the CatBoost-style *oblivious* constraint), which is what makes the
//! trained model a fixed-shape tensor program.
//!
//! Two engines share the same candidate-threshold set and tie-breaks:
//!
//! * [`train`] — the production histogram engine.  Features are
//!   quantized once into `u8` bin codes ([`super::hist`]); each level
//!   builds per-(leaf, feature) gradient/count histograms in one
//!   O(n·F) pass and evaluates *every* candidate threshold by scanning
//!   bin suffix sums in O(leaves·F·bins), so the per-level cost is
//!   O(n·F + leaves·F·bins) instead of the exact engine's O(F·bins·n).
//!   On large training sets the per-level histogram+scan pass forks
//!   one task per feature across the process-wide worker pool
//!   ([`crate::util::parallel`]): each (leaf, feature, bin) cell has a
//!   single writer and the best-split arg-max reduces in feature
//!   order, so the trained ensemble is **bit-identical for every
//!   worker count** (pinned by `tests/parallel_invariance.rs`).
//! * [`train_exact`] — the original brute-force engine that rescans all
//!   samples per candidate.  Kept as the differential-testing oracle
//!   (`tests/tuning_properties.rs` pins the histogram engine's holdout
//!   quality against it); both are bit-deterministic for fixed inputs.

use super::ensemble::Ensemble;
use super::hist::{candidate_thresholds, BinnedDataset, FeatureHist, LevelHistogram, PAR_MIN_CELLS};
use crate::config::F_MAX;
use crate::util::parallel;

/// Training hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct GbtParams {
    pub n_trees: usize,
    pub depth: usize,
    pub learning_rate: f64,
    /// L2 leaf regularization (xgboost lambda).
    pub lambda: f64,
    /// Candidate thresholds per feature (quantile bins, capped at
    /// [`super::hist::MAX_THRESHOLDS`]).
    pub n_bins: usize,
    /// Minimum summed hessian per child for a split to count.
    pub min_child_weight: f64,
}

impl Default for GbtParams {
    fn default() -> Self {
        GbtParams {
            n_trees: 48,
            depth: 4,
            learning_rate: 0.12,
            lambda: 1.0,
            n_bins: 32,
            min_child_weight: 1.0,
        }
    }
}

impl GbtParams {
    /// Settings tuned for very small sample counts (25-100 workflow
    /// runs — the paper's budgets).
    pub fn small_data() -> Self {
        GbtParams {
            n_trees: 40,
            depth: 3,
            learning_rate: 0.15,
            lambda: 1.5,
            n_bins: 16,
            min_child_weight: 1.0,
        }
    }
}

/// Train an oblivious-GBT regressor in LOG space: the model predicts
/// ln(y), so exp(prediction) is the time estimate.  Times span orders
/// of magnitude across a configuration space; fitting in log space
/// stops the squared loss being dominated by the catastrophic configs
/// and sharpens ranking among the top ones (which is what the paper's
/// searcher needs).  All y must be positive.
pub fn train_log(xs: &[[f32; F_MAX]], y: &[f64], n_features: usize, p: &GbtParams) -> Ensemble {
    train(xs, &ln_targets(y), n_features, p)
}

/// Log-space variant of [`train_exact`] (benchmark baseline).
pub fn train_log_exact(
    xs: &[[f32; F_MAX]],
    y: &[f64],
    n_features: usize,
    p: &GbtParams,
) -> Ensemble {
    train_exact(xs, &ln_targets(y), n_features, p)
}

fn ln_targets(y: &[f64]) -> Vec<f64> {
    assert!(
        y.iter().all(|&v| v > 0.0),
        "log-space training requires positive targets"
    );
    y.iter().map(|&v| v.ln()).collect()
}

/// Shared entry validation + degenerate-input handling; returns the
/// bias and sample count when training should proceed.
fn prepare(xs: &[[f32; F_MAX]], y: &[f64], n_features: usize, p: &GbtParams) -> Result<f64, Ensemble> {
    assert_eq!(xs.len(), y.len(), "xs/y length mismatch");
    assert!(n_features >= 1 && n_features <= F_MAX);
    let n = xs.len();
    if n == 0 {
        return Err(Ensemble::constant(n_features, 0.0));
    }
    let bias = y.iter().sum::<f64>() / n as f64;
    if n == 1 || p.n_trees == 0 {
        return Err(Ensemble::constant(n_features, bias as f32));
    }
    Ok(bias)
}

/// Train an oblivious-GBT regressor on `(xs, y)` with histogram-binned
/// split search (the production engine — see module docs).
///
/// `n_features` restricts split search to the first `n_features`
/// columns (the rest are padding).  Targets are typically execution or
/// computer times; callers may log-transform if desired.
pub fn train(xs: &[[f32; F_MAX]], y: &[f64], n_features: usize, p: &GbtParams) -> Ensemble {
    let bias = match prepare(xs, y, n_features, p) {
        Ok(b) => b,
        Err(degenerate) => return degenerate,
    };
    let n = xs.len();
    let leaves_w = 1usize << p.depth;
    let mut pred = vec![bias; n];
    let mut feat_out: Vec<u32> = Vec::with_capacity(p.n_trees * p.depth);
    let mut thr_out: Vec<f32> = Vec::with_capacity(p.n_trees * p.depth);
    let mut leaves_out: Vec<f32> = Vec::with_capacity(p.n_trees * leaves_w);

    // Quantize every feature once; all trees share the bin codes.
    let binned = BinnedDataset::build(xs, n_features, p.n_bins);
    // >= n_features: even a constant feature owns one bin.
    let stride = binned.total_bins;
    // Scratch reused across levels/trees (peak size: deepest level).
    let mut hist = LevelHistogram::new(leaves_w, stride);
    // Fork-join width for the per-level histogram+scan job; task
    // boundaries (one per feature) never depend on it, so the trained
    // ensemble is bit-identical for every worker count.
    let width = parallel::width_for(n * n_features, PAR_MIN_CELLS);
    let mut grad = vec![0.0f64; n];
    let mut idx = vec![0usize; n];

    for _tree in 0..p.n_trees {
        build_gradient(&mut grad, &pred, y, width);
        // leaf assignment as we grow levels
        idx.iter_mut().for_each(|v| *v = 0);
        let mut tree_feat = vec![0u32; p.depth];
        let mut tree_thr = vec![f32::INFINITY; p.depth];

        for d in 0..p.depth {
            let n_leaves = 1usize << d;
            // One fused fork-join per level: each feature's task zeroes
            // and refills its own histogram columns (one writer per
            // (leaf, feature, bin) cell — no merge), then scans its own
            // candidate cuts, returning its best (gain, cut).
            let best_per_f = hist.fill_scan(&binned, &idx, &grad, n_leaves, width, |f, h| {
                scan_feature(&binned, p, n_leaves, f, &h)
            });
            // Ordered reduction, ascending f with strict `>`: identical
            // arg-max tie-breaks to the sequential and exact engines,
            // regardless of which worker scanned which feature.
            let mut best: Option<(f64, usize, usize)> = None; // (gain, f, cut)
            for (f, bf) in best_per_f.iter().enumerate() {
                if let Some((gain, k)) = *bf {
                    if best.map(|(bg, _, _)| gain > bg).unwrap_or(true) {
                        best = Some((gain, f, k));
                    }
                }
            }
            match best {
                Some((_, f, k)) => {
                    tree_feat[d] = f as u32;
                    tree_thr[d] = binned.thresholds[f][k];
                    let codes = binned.feature_codes(f);
                    let cut = k as u8;
                    for (v, &c) in idx.iter_mut().zip(codes) {
                        if c > cut {
                            *v |= 1 << d;
                        }
                    }
                }
                None => {
                    // no useful split at this level: +inf threshold is a
                    // structural no-op (everything keeps bit 0)
                    tree_feat[d] = 0;
                    tree_thr[d] = f32::INFINITY;
                }
            }
        }

        finish_tree(
            p, n, &grad, &idx, leaves_w, &mut pred, &tree_feat, &tree_thr, &mut feat_out,
            &mut thr_out, &mut leaves_out,
        );
    }

    Ensemble {
        n_features,
        depth: p.depth,
        feat: feat_out,
        thr: thr_out,
        leaves: leaves_out,
        bias: bias as f32,
    }
}

/// `grad[i] = pred[i] - y[i]`, element-wise over fixed 1024-element
/// chunks — the chunk layout depends only on `n`, so the pass is
/// bit-identical for every worker count.
fn build_gradient(grad: &mut [f64], pred: &[f64], y: &[f64], width: usize) {
    const CHUNK: usize = 1024;
    parallel::for_each_chunk_mut(width, CHUNK, grad, |ci, out| {
        let base = ci * CHUNK;
        for (k, g) in out.iter_mut().enumerate() {
            *g = pred[base + k] - y[base + k];
        }
    });
}

/// Per-worker split-scan scratch (leaf totals + suffix sums): pool
/// workers are persistent, so the per-level feature tasks allocate
/// nothing once their worker is warm, matching the old engine's
/// hoisted scratch.
#[derive(Default)]
struct ScanScratch {
    leaf_g: Vec<f64>,
    leaf_c: Vec<u32>,
    right_g: Vec<f64>,
    right_c: Vec<u32>,
}

std::thread_local! {
    static SCAN_SCRATCH: std::cell::RefCell<ScanScratch> =
        std::cell::RefCell::new(ScanScratch::default());
}

/// Best (gain, cut) of feature `f` at one tree level, from its freshly
/// filled histogram columns (runs inside that feature's fill task).
///
/// Per-leaf gradient/count totals are recovered from the feature's own
/// bins — every feature's bins partition the rows, so the counts are
/// the exact row counts and the scan needs no cross-feature state.
/// Cuts are walked descending while the suffix sums accumulate; `>=`
/// keeps the lowest cut among exact ties, matching the exact engine's
/// ascending strict-`>` scan.
fn scan_feature(
    binned: &BinnedDataset,
    p: &GbtParams,
    n_leaves: usize,
    f: usize,
    h: &FeatureHist<'_>,
) -> Option<(f64, usize)> {
    let n_thr = binned.thresholds[f].len();
    if n_thr == 0 {
        return None;
    }
    SCAN_SCRATCH.with(|scratch| {
        let mut scratch = scratch.borrow_mut();
        let ScanScratch {
            leaf_g,
            leaf_c,
            right_g,
            right_c,
        } = &mut *scratch;
        leaf_g.clear();
        leaf_g.resize(n_leaves, 0.0);
        leaf_c.clear();
        leaf_c.resize(n_leaves, 0);
        right_g.clear();
        right_g.resize(n_leaves, 0.0);
        right_c.clear();
        right_c.resize(n_leaves, 0);
        let mut parent_score = 0.0f64;
        for l in 0..n_leaves {
            let mut g = 0.0f64;
            let mut c = 0u32;
            for b in 0..=n_thr {
                g += h.grad(l, b);
                c += h.count(l, b);
            }
            leaf_g[l] = g;
            leaf_c[l] = c;
            parent_score += g * g / (c as f64 + p.lambda);
        }
        let mut best: Option<(f64, usize)> = None;
        for k in (0..n_thr).rev() {
            let mut score = 0.0f64;
            let mut valid = false;
            for l in 0..n_leaves {
                right_g[l] += h.grad(l, k + 1);
                right_c[l] += h.count(l, k + 1);
                let hr = right_c[l] as f64;
                let hl = (leaf_c[l] - right_c[l]) as f64;
                let gr = right_g[l];
                let gl = leaf_g[l] - gr;
                if hl >= p.min_child_weight && hr >= p.min_child_weight {
                    valid = true;
                    score += gl * gl / (hl + p.lambda) + gr * gr / (hr + p.lambda);
                } else {
                    // unsplit leaf keeps parent contribution
                    let g = leaf_g[l];
                    let hp = leaf_c[l] as f64;
                    score += g * g / (hp + p.lambda);
                }
            }
            if !valid {
                continue;
            }
            let gain = score - parent_score;
            if gain > 1e-12 && best.map(|(bg, _)| gain >= bg).unwrap_or(true) {
                best = Some((gain, k));
            }
        }
        best
    })
}

/// Leaf-weight solve + prediction update + tree emission, shared by
/// both engines so their outputs agree given identical splits.
#[allow(clippy::too_many_arguments)]
fn finish_tree(
    p: &GbtParams,
    n: usize,
    grad: &[f64],
    idx: &[usize],
    leaves_w: usize,
    pred: &mut [f64],
    tree_feat: &[u32],
    tree_thr: &[f32],
    feat_out: &mut Vec<u32>,
    thr_out: &mut Vec<f32>,
    leaves_out: &mut Vec<f32>,
) {
    // leaf weights: w = -lr * G/(H + lambda)
    let mut leaf_g = vec![0.0f64; leaves_w];
    let mut leaf_h = vec![0.0f64; leaves_w];
    for i in 0..n {
        leaf_g[idx[i]] += grad[i];
        leaf_h[idx[i]] += 1.0;
    }
    let mut leaves = vec![0.0f32; leaves_w];
    for l in 0..leaves_w {
        if leaf_h[l] > 0.0 {
            leaves[l] = (-p.learning_rate * leaf_g[l] / (leaf_h[l] + p.lambda)) as f32;
        }
    }
    for i in 0..n {
        pred[i] += leaves[idx[i]] as f64;
    }
    feat_out.extend_from_slice(tree_feat);
    thr_out.extend_from_slice(tree_thr);
    leaves_out.extend_from_slice(&leaves);
}

/// The pre-histogram brute-force engine: every candidate threshold
/// rescans all samples (O(F·bins·n) per level).  Same candidate set,
/// gain formula and tie-breaks as [`train`]; kept as the differential
/// oracle and benchmark baseline.
pub fn train_exact(xs: &[[f32; F_MAX]], y: &[f64], n_features: usize, p: &GbtParams) -> Ensemble {
    let bias = match prepare(xs, y, n_features, p) {
        Ok(b) => b,
        Err(degenerate) => return degenerate,
    };
    let n = xs.len();
    let leaves_w = 1usize << p.depth;
    let mut pred = vec![bias; n];
    let mut feat_out: Vec<u32> = Vec::with_capacity(p.n_trees * p.depth);
    let mut thr_out: Vec<f32> = Vec::with_capacity(p.n_trees * p.depth);
    let mut leaves_out: Vec<f32> = Vec::with_capacity(p.n_trees * leaves_w);

    // Per-feature candidate thresholds are data-determined once.
    let cands: Vec<Vec<f32>> = (0..n_features)
        .map(|f| candidate_thresholds(xs, f, p.n_bins))
        .collect();

    for _tree in 0..p.n_trees {
        let grad: Vec<f64> = (0..n).map(|i| pred[i] - y[i]).collect();
        let mut idx = vec![0usize; n];
        let mut tree_feat = vec![0u32; p.depth];
        let mut tree_thr = vec![f32::INFINITY; p.depth];

        for d in 0..p.depth {
            let n_leaves = 1usize << d;
            let mut leaf_g = vec![0.0f64; n_leaves];
            let mut leaf_h = vec![0.0f64; n_leaves];
            for i in 0..n {
                leaf_g[idx[i]] += grad[i];
                leaf_h[idx[i]] += 1.0;
            }
            let parent_score: f64 = (0..n_leaves)
                .map(|l| leaf_g[l] * leaf_g[l] / (leaf_h[l] + p.lambda))
                .sum();

            let mut best: Option<(f64, usize, f32)> = None;
            for f in 0..n_features {
                for &thr in &cands[f] {
                    let mut right_g = vec![0.0f64; n_leaves];
                    let mut right_h = vec![0.0f64; n_leaves];
                    for i in 0..n {
                        if xs[i][f] > thr {
                            right_g[idx[i]] += grad[i];
                            right_h[idx[i]] += 1.0;
                        }
                    }
                    let mut score = 0.0f64;
                    let mut valid = false;
                    for l in 0..n_leaves {
                        let (gl, hl) = (leaf_g[l] - right_g[l], leaf_h[l] - right_h[l]);
                        let (gr, hr) = (right_g[l], right_h[l]);
                        if hl >= p.min_child_weight && hr >= p.min_child_weight {
                            valid = true;
                            score += gl * gl / (hl + p.lambda) + gr * gr / (hr + p.lambda);
                        } else {
                            // unsplit leaf keeps parent contribution
                            let g = leaf_g[l];
                            let h = leaf_h[l];
                            score += g * g / (h + p.lambda);
                        }
                    }
                    if !valid {
                        continue;
                    }
                    let gain = score - parent_score;
                    if gain > 1e-12 && best.map(|(bg, _, _)| gain > bg).unwrap_or(true) {
                        best = Some((gain, f, thr));
                    }
                }
            }
            match best {
                Some((_, f, thr)) => {
                    tree_feat[d] = f as u32;
                    tree_thr[d] = thr;
                    for i in 0..n {
                        if xs[i][f] > thr {
                            idx[i] |= 1 << d;
                        }
                    }
                }
                None => {
                    tree_feat[d] = 0;
                    tree_thr[d] = f32::INFINITY;
                }
            }
        }

        finish_tree(
            p, n, &grad, &idx, leaves_w, &mut pred, &tree_feat, &tree_thr, &mut feat_out,
            &mut thr_out, &mut leaves_out,
        );
    }

    Ensemble {
        n_features,
        depth: p.depth,
        feat: feat_out,
        thr: thr_out,
        leaves: leaves_out,
        bias: bias as f32,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use crate::util::stats;

    fn make_data(
        rng: &mut Pcg32,
        n: usize,
        f: impl Fn(&[f32; F_MAX]) -> f64,
    ) -> (Vec<[f32; F_MAX]>, Vec<f64>) {
        let xs: Vec<[f32; F_MAX]> = (0..n)
            .map(|_| {
                let mut x = [0f32; F_MAX];
                for v in x.iter_mut() {
                    *v = rng.f32();
                }
                x
            })
            .collect();
        let y: Vec<f64> = xs.iter().map(&f).collect();
        (xs, y)
    }

    fn rmse(e: &Ensemble, xs: &[[f32; F_MAX]], y: &[f64]) -> f64 {
        let se: f64 = xs
            .iter()
            .zip(y)
            .map(|(x, &t)| {
                let p = e.predict(x) as f64;
                (p - t) * (p - t)
            })
            .sum();
        (se / y.len() as f64).sqrt()
    }

    #[test]
    fn fits_constant() {
        let xs = vec![[0.5f32; F_MAX]; 10];
        let y = vec![3.0; 10];
        let e = train(&xs, &y, 4, &GbtParams::default());
        assert!((e.predict(&xs[0]) - 3.0).abs() < 1e-3);
    }

    #[test]
    fn fits_step_function() {
        let mut rng = Pcg32::new(1, 0);
        let (xs, y) = make_data(&mut rng, 200, |x| if x[2] > 0.5 { 10.0 } else { 1.0 });
        let e = train(&xs, &y, 4, &GbtParams::default());
        assert!(rmse(&e, &xs, &y) < 0.5, "rmse {}", rmse(&e, &xs, &y));
    }

    #[test]
    fn fits_additive_nonlinear() {
        let mut rng = Pcg32::new(2, 0);
        let f = |x: &[f32; F_MAX]| {
            5.0 * (x[0] as f64) + 3.0 * ((x[1] as f64) - 0.5).powi(2) + (x[3] as f64).sqrt()
        };
        let (xs, y) = make_data(&mut rng, 400, f);
        let e = train(&xs, &y, 5, &GbtParams::default());
        let spread = stats::std_dev(&y);
        let err = rmse(&e, &xs, &y);
        assert!(err < spread * 0.25, "rmse {err} vs spread {spread}");
    }

    #[test]
    fn generalizes_on_holdout() {
        let mut rng = Pcg32::new(3, 0);
        let f = |x: &[f32; F_MAX]| 4.0 * (x[0] as f64) * (x[1] as f64) + 2.0 * x[2] as f64;
        let (xs, y) = make_data(&mut rng, 500, f);
        let (tx, ty) = make_data(&mut rng, 200, f);
        let e = train(&xs, &y, 4, &GbtParams::default());
        let err = rmse(&e, &tx, &ty);
        let spread = stats::std_dev(&ty);
        assert!(err < spread * 0.4, "holdout rmse {err} vs spread {spread}");
    }

    #[test]
    fn small_sample_budget_works() {
        // 25 samples, the paper's smallest budget — must not blow up.
        let mut rng = Pcg32::new(4, 0);
        let f = |x: &[f32; F_MAX]| 100.0 * x[0] as f64 + 10.0;
        let (xs, y) = make_data(&mut rng, 25, f);
        let e = train(&xs, &y, 3, &GbtParams::small_data());
        // monotone recovery: predictions correlate with x0
        let lo = e.predict(&{
            let mut v = [0.5f32; F_MAX];
            v[0] = 0.05;
            v
        });
        let hi = e.predict(&{
            let mut v = [0.5f32; F_MAX];
            v[0] = 0.95;
            v
        });
        assert!(hi > lo + 20.0, "lo {lo} hi {hi}");
    }

    #[test]
    fn flattened_matches_native_after_training() {
        let mut rng = Pcg32::new(5, 0);
        let f = |x: &[f32; F_MAX]| (x[0] as f64) * 7.0 - (x[1] as f64) * 2.0;
        let (xs, y) = make_data(&mut rng, 150, f);
        let e = train(&xs, &y, 4, &GbtParams::default());
        let flat = e.flatten();
        for x in xs.iter().take(40) {
            let a = e.predict(x);
            let b = flat.predict(x);
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn degenerate_inputs() {
        for engine in [train as fn(&[[f32; F_MAX]], &[f64], usize, &GbtParams) -> Ensemble, train_exact] {
            // empty
            let e = engine(&[], &[], 2, &GbtParams::default());
            assert_eq!(e.predict(&[0.0; F_MAX]), 0.0);
            // single sample
            let e1 = engine(&[[0.1; F_MAX]], &[5.0], 2, &GbtParams::default());
            assert!((e1.predict(&[0.9; F_MAX]) - 5.0).abs() < 1e-6);
        }
    }

    #[test]
    fn deterministic() {
        let mut rng = Pcg32::new(6, 0);
        let (xs, y) = make_data(&mut rng, 60, |x| x[0] as f64);
        let a = train(&xs, &y, 2, &GbtParams::default());
        let b = train(&xs, &y, 2, &GbtParams::default());
        assert_eq!(a, b);
    }

    #[test]
    fn exact_engine_deterministic() {
        let mut rng = Pcg32::new(6, 1);
        let (xs, y) = make_data(&mut rng, 60, |x| x[0] as f64);
        let a = train_exact(&xs, &y, 2, &GbtParams::default());
        let b = train_exact(&xs, &y, 2, &GbtParams::default());
        assert_eq!(a, b);
    }

    #[test]
    fn histogram_engine_tracks_exact_engine() {
        // Same candidate sets and tie-breaks: in-sample fits of the two
        // engines must be statistically indistinguishable (they may
        // pick different near-tied splits only through last-bit f64
        // rounding of the gradient sums — the histogram engine folds
        // leaf totals in bin order, the exact engine in row order).
        let mut rng = Pcg32::new(7, 0);
        let f = |x: &[f32; F_MAX]| {
            20.0 * (x[0] as f64) + 8.0 * (x[1] as f64) * (x[2] as f64)
                - 5.0 * ((x[3] as f64) - 0.4).powi(2)
        };
        for n in [30usize, 120, 400] {
            let (xs, y) = make_data(&mut rng, n, f);
            let (tx, ty) = make_data(&mut rng, 150, f);
            for params in [GbtParams::default(), GbtParams::small_data()] {
                let h = train(&xs, &y, 5, &params);
                let e = train_exact(&xs, &y, 5, &params);
                let (rh, re) = (rmse(&h, &tx, &ty), rmse(&e, &tx, &ty));
                let spread = stats::std_dev(&ty);
                assert!(
                    (rh - re).abs() <= 0.05 * spread + 1e-9,
                    "n={n}: hist rmse {rh} vs exact rmse {re} (spread {spread})"
                );
            }
        }
    }
}
