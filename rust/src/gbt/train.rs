//! Oblivious-GBT training: second-order gradient boosting with
//! histogram-binned split search, level-shared splits, shrinkage and L2
//! regularization — the from-scratch xgboost substitute.
//!
//! Squared-error objective: gradients `g_i = pred_i - y_i`, hessians
//! `h_i = 1`.  At every tree level the single (feature, threshold) pair
//! maximizing the summed split gain across all current leaves is chosen
//! (the CatBoost-style *oblivious* constraint), which is what makes the
//! trained model a fixed-shape tensor program.
//!
//! Two engines share the same candidate-threshold set and tie-breaks:
//!
//! * [`train`] — the production histogram engine.  Features are
//!   quantized once into `u8` bin codes ([`super::hist`]); each level
//!   builds per-(leaf, feature) gradient/count histograms in one
//!   O(n·F) pass and evaluates *every* candidate threshold by scanning
//!   bin suffix sums in O(leaves·F·bins), so the per-level cost is
//!   O(n·F + leaves·F·bins) instead of the exact engine's O(F·bins·n).
//!   On large training sets the per-level histogram+scan pass forks
//!   one task per feature across the process-wide worker pool
//!   ([`crate::util::parallel`]): each (leaf, feature, bin) cell has a
//!   single writer and the best-split arg-max reduces in feature
//!   order, so the trained ensemble is **bit-identical for every
//!   worker count** (pinned by `tests/parallel_invariance.rs`).
//! * [`train_exact`] — the original brute-force engine that rescans all
//!   samples per candidate.  Kept as the differential-testing oracle
//!   (`tests/tuning_properties.rs` pins the histogram engine's holdout
//!   quality against it); both are bit-deterministic for fixed inputs.

use super::ensemble::Ensemble;
use super::hist::{candidate_thresholds, BinnedDataset, FeatureHist, LevelHistogram, PAR_MIN_CELLS};
use crate::config::F_MAX;
use crate::util::parallel;

/// Training hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct GbtParams {
    pub n_trees: usize,
    pub depth: usize,
    pub learning_rate: f64,
    /// L2 leaf regularization (xgboost lambda).
    pub lambda: f64,
    /// Candidate thresholds per feature (quantile bins, capped at
    /// [`super::hist::MAX_THRESHOLDS`]).
    pub n_bins: usize,
    /// Minimum summed hessian per child for a split to count.
    pub min_child_weight: f64,
}

impl Default for GbtParams {
    fn default() -> Self {
        GbtParams {
            n_trees: 48,
            depth: 4,
            learning_rate: 0.12,
            lambda: 1.0,
            n_bins: 32,
            min_child_weight: 1.0,
        }
    }
}

impl GbtParams {
    /// Settings tuned for very small sample counts (25-100 workflow
    /// runs — the paper's budgets).
    pub fn small_data() -> Self {
        GbtParams {
            n_trees: 40,
            depth: 3,
            learning_rate: 0.15,
            lambda: 1.5,
            n_bins: 16,
            min_child_weight: 1.0,
        }
    }
}

/// Train an oblivious-GBT regressor in LOG space: the model predicts
/// ln(y), so exp(prediction) is the time estimate.  Times span orders
/// of magnitude across a configuration space; fitting in log space
/// stops the squared loss being dominated by the catastrophic configs
/// and sharpens ranking among the top ones (which is what the paper's
/// searcher needs).  All y must be positive.
pub fn train_log(xs: &[[f32; F_MAX]], y: &[f64], n_features: usize, p: &GbtParams) -> Ensemble {
    train(xs, &ln_targets(y), n_features, p)
}

/// Log-space variant of [`train_exact`] (benchmark baseline).
pub fn train_log_exact(
    xs: &[[f32; F_MAX]],
    y: &[f64],
    n_features: usize,
    p: &GbtParams,
) -> Ensemble {
    train_exact(xs, &ln_targets(y), n_features, p)
}

fn ln_targets(y: &[f64]) -> Vec<f64> {
    assert!(
        y.iter().all(|&v| v > 0.0),
        "log-space training requires positive targets"
    );
    y.iter().map(|&v| v.ln()).collect()
}

/// Shared entry validation + degenerate-input handling; returns the
/// bias and sample count when training should proceed.
fn prepare(xs: &[[f32; F_MAX]], y: &[f64], n_features: usize, p: &GbtParams) -> Result<f64, Ensemble> {
    assert_eq!(xs.len(), y.len(), "xs/y length mismatch");
    prepare_targets(y, n_features, p)
}

/// The target-side half of [`prepare`] — shared with the pre-binned
/// entry point, which has no feature matrix to length-check.
fn prepare_targets(y: &[f64], n_features: usize, p: &GbtParams) -> Result<f64, Ensemble> {
    assert!(n_features >= 1 && n_features <= F_MAX);
    let n = y.len();
    if n == 0 {
        return Err(Ensemble::constant(n_features, 0.0));
    }
    let bias = y.iter().sum::<f64>() / n as f64;
    if n == 1 || p.n_trees == 0 {
        return Err(Ensemble::constant(n_features, bias as f32));
    }
    Ok(bias)
}

/// Train an oblivious-GBT regressor on `(xs, y)` with histogram-binned
/// split search (the production engine — see module docs).
///
/// `n_features` restricts split search to the first `n_features`
/// columns (the rest are padding).  Targets are typically execution or
/// computer times; callers may log-transform if desired.
pub fn train(xs: &[[f32; F_MAX]], y: &[f64], n_features: usize, p: &GbtParams) -> Ensemble {
    let bias = match prepare(xs, y, n_features, p) {
        Ok(b) => b,
        Err(degenerate) => return degenerate,
    };
    // Quantize every feature once; all trees share the bin codes.
    let binned = BinnedDataset::build(xs, n_features, p.n_bins);
    train_core(y, n_features, p, &binned, bias)
}

/// Log-space histogram training over an already-binned dataset — the
/// incremental refit path.  `binned` must cover exactly the rows `y`
/// labels (built or extended via [`BinnedDataset::push_rows`] from the
/// same feature rows and bin budget); the result is bitwise-identical
/// to [`train_log`] over those rows, because training reads features
/// only through the bin codes and push_rows keeps those codes equal to
/// a from-scratch rebuild.
pub fn train_log_binned(
    binned: &BinnedDataset,
    y: &[f64],
    n_features: usize,
    p: &GbtParams,
) -> Ensemble {
    assert_eq!(binned.n_rows, y.len(), "binned/y length mismatch");
    assert_eq!(binned.n_features, n_features, "binned/n_features mismatch");
    let ln = ln_targets(y);
    let bias = match prepare_targets(&ln, n_features, p) {
        Ok(b) => b,
        Err(degenerate) => return degenerate,
    };
    train_core(&ln, n_features, p, binned, bias)
}

/// The boosting loop both [`train`] and [`train_log_binned`] share:
/// everything after binning.  Features are read exclusively through
/// `binned`'s codes and thresholds.
fn train_core(
    y: &[f64],
    n_features: usize,
    p: &GbtParams,
    binned: &BinnedDataset,
    bias: f64,
) -> Ensemble {
    let n = binned.n_rows;
    let leaves_w = 1usize << p.depth;
    let mut pred = vec![bias; n];
    let mut feat_out: Vec<u32> = Vec::with_capacity(p.n_trees * p.depth);
    let mut thr_out: Vec<f32> = Vec::with_capacity(p.n_trees * p.depth);
    let mut leaves_out: Vec<f32> = Vec::with_capacity(p.n_trees * leaves_w);

    // >= n_features: even a constant feature owns one bin.
    let stride = binned.total_bins;
    // Scratch reused across levels/trees (peak size: deepest level).
    let mut hist = LevelHistogram::new(leaves_w, stride);
    // Fork-join width for the per-level histogram+scan job; task
    // boundaries (one per feature) never depend on it, so the trained
    // ensemble is bit-identical for every worker count.
    let width = parallel::width_for(n * n_features, PAR_MIN_CELLS);
    let mut grad = vec![0.0f64; n];
    let mut idx = vec![0usize; n];

    for _tree in 0..p.n_trees {
        build_gradient(&mut grad, &pred, y, width);
        // leaf assignment as we grow levels
        idx.iter_mut().for_each(|v| *v = 0);
        let mut tree_feat = vec![0u32; p.depth];
        let mut tree_thr = vec![f32::INFINITY; p.depth];

        for d in 0..p.depth {
            let n_leaves = 1usize << d;
            // One fused fork-join per level: each feature's task zeroes
            // and refills its own histogram columns (one writer per
            // (leaf, feature, bin) cell — no merge), then scans its own
            // candidate cuts, returning its best (gain, cut).
            let best_per_f = hist.fill_scan(&binned, &idx, &grad, n_leaves, width, |f, h| {
                scan_feature(&binned, p, n_leaves, f, &h)
            });
            // Ordered reduction, ascending f with strict `>`: identical
            // arg-max tie-breaks to the sequential and exact engines,
            // regardless of which worker scanned which feature.
            let mut best: Option<(f64, usize, usize)> = None; // (gain, f, cut)
            for (f, bf) in best_per_f.iter().enumerate() {
                if let Some((gain, k)) = *bf {
                    if best.map(|(bg, _, _)| gain > bg).unwrap_or(true) {
                        best = Some((gain, f, k));
                    }
                }
            }
            match best {
                Some((_, f, k)) => {
                    tree_feat[d] = f as u32;
                    tree_thr[d] = binned.thresholds[f][k];
                    let codes = binned.feature_codes(f);
                    let cut = k as u8;
                    for (v, &c) in idx.iter_mut().zip(codes) {
                        if c > cut {
                            *v |= 1 << d;
                        }
                    }
                }
                None => {
                    // no useful split at this level: +inf threshold is a
                    // structural no-op (everything keeps bit 0)
                    tree_feat[d] = 0;
                    tree_thr[d] = f32::INFINITY;
                }
            }
        }

        finish_tree(
            p, n, &grad, &idx, leaves_w, &mut pred, &tree_feat, &tree_thr, &mut feat_out,
            &mut thr_out, &mut leaves_out,
        );
    }

    Ensemble {
        n_features,
        depth: p.depth,
        feat: feat_out,
        thr: thr_out,
        leaves: leaves_out,
        bias: bias as f32,
    }
}

/// `grad[i] = pred[i] - y[i]`, element-wise over fixed 1024-element
/// chunks — the chunk layout depends only on `n`, so the pass is
/// bit-identical for every worker count.
fn build_gradient(grad: &mut [f64], pred: &[f64], y: &[f64], width: usize) {
    const CHUNK: usize = 1024;
    parallel::for_each_chunk_mut(width, CHUNK, grad, |ci, out| {
        let base = ci * CHUNK;
        for (k, g) in out.iter_mut().enumerate() {
            *g = pred[base + k] - y[base + k];
        }
    });
}

/// Per-worker split-scan scratch (leaf totals + suffix sums): pool
/// workers are persistent, so the per-level feature tasks allocate
/// nothing once their worker is warm, matching the old engine's
/// hoisted scratch.
#[derive(Default)]
struct ScanScratch {
    leaf_g: Vec<f64>,
    leaf_c: Vec<u32>,
    right_g: Vec<f64>,
    right_c: Vec<u32>,
}

std::thread_local! {
    static SCAN_SCRATCH: std::cell::RefCell<ScanScratch> =
        std::cell::RefCell::new(ScanScratch::default());
}

/// Best (gain, cut) of feature `f` at one tree level, from its freshly
/// filled histogram columns (runs inside that feature's fill task).
///
/// Per-leaf gradient/count totals are recovered from the feature's own
/// bins — every feature's bins partition the rows, so the counts are
/// the exact row counts and the scan needs no cross-feature state.
/// Cuts are walked descending while the suffix sums accumulate; `>=`
/// keeps the lowest cut among exact ties, matching the exact engine's
/// ascending strict-`>` scan.
fn scan_feature(
    binned: &BinnedDataset,
    p: &GbtParams,
    n_leaves: usize,
    f: usize,
    h: &FeatureHist<'_>,
) -> Option<(f64, usize)> {
    let n_thr = binned.thresholds[f].len();
    if n_thr == 0 {
        return None;
    }
    SCAN_SCRATCH.with(|scratch| {
        let mut scratch = scratch.borrow_mut();
        let ScanScratch {
            leaf_g,
            leaf_c,
            right_g,
            right_c,
        } = &mut *scratch;
        leaf_g.clear();
        leaf_g.resize(n_leaves, 0.0);
        leaf_c.clear();
        leaf_c.resize(n_leaves, 0);
        right_g.clear();
        right_g.resize(n_leaves, 0.0);
        right_c.clear();
        right_c.resize(n_leaves, 0);
        let mut parent_score = 0.0f64;
        for l in 0..n_leaves {
            let mut g = 0.0f64;
            let mut c = 0u32;
            for b in 0..=n_thr {
                g += h.grad(l, b);
                c += h.count(l, b);
            }
            leaf_g[l] = g;
            leaf_c[l] = c;
            parent_score += g * g / (c as f64 + p.lambda);
        }
        let mut best: Option<(f64, usize)> = None;
        for k in (0..n_thr).rev() {
            let mut score = 0.0f64;
            let mut valid = false;
            for l in 0..n_leaves {
                right_g[l] += h.grad(l, k + 1);
                right_c[l] += h.count(l, k + 1);
                let hr = right_c[l] as f64;
                let hl = (leaf_c[l] - right_c[l]) as f64;
                let gr = right_g[l];
                let gl = leaf_g[l] - gr;
                if hl >= p.min_child_weight && hr >= p.min_child_weight {
                    valid = true;
                    score += gl * gl / (hl + p.lambda) + gr * gr / (hr + p.lambda);
                } else {
                    // unsplit leaf keeps parent contribution
                    let g = leaf_g[l];
                    let hp = leaf_c[l] as f64;
                    score += g * g / (hp + p.lambda);
                }
            }
            if !valid {
                continue;
            }
            let gain = score - parent_score;
            if gain > 1e-12 && best.map(|(bg, _)| gain >= bg).unwrap_or(true) {
                best = Some((gain, k));
            }
        }
        best
    })
}

/// Leaf-weight solve + prediction update + tree emission, shared by
/// both engines so their outputs agree given identical splits.
#[allow(clippy::too_many_arguments)]
fn finish_tree(
    p: &GbtParams,
    n: usize,
    grad: &[f64],
    idx: &[usize],
    leaves_w: usize,
    pred: &mut [f64],
    tree_feat: &[u32],
    tree_thr: &[f32],
    feat_out: &mut Vec<u32>,
    thr_out: &mut Vec<f32>,
    leaves_out: &mut Vec<f32>,
) {
    // leaf weights: w = -lr * G/(H + lambda)
    let mut leaf_g = vec![0.0f64; leaves_w];
    let mut leaf_h = vec![0.0f64; leaves_w];
    for i in 0..n {
        leaf_g[idx[i]] += grad[i];
        leaf_h[idx[i]] += 1.0;
    }
    let mut leaves = vec![0.0f32; leaves_w];
    for l in 0..leaves_w {
        if leaf_h[l] > 0.0 {
            leaves[l] = (-p.learning_rate * leaf_g[l] / (leaf_h[l] + p.lambda)) as f32;
        }
    }
    for i in 0..n {
        pred[i] += leaves[idx[i]] as f64;
    }
    feat_out.extend_from_slice(tree_feat);
    thr_out.extend_from_slice(tree_thr);
    leaves_out.extend_from_slice(&leaves);
}

/// The pre-histogram brute-force engine: every candidate threshold
/// rescans all samples (O(F·bins·n) per level).  Same candidate set,
/// gain formula and tie-breaks as [`train`]; kept as the differential
/// oracle and benchmark baseline.
pub fn train_exact(xs: &[[f32; F_MAX]], y: &[f64], n_features: usize, p: &GbtParams) -> Ensemble {
    let bias = match prepare(xs, y, n_features, p) {
        Ok(b) => b,
        Err(degenerate) => return degenerate,
    };
    let n = xs.len();
    let leaves_w = 1usize << p.depth;
    let mut pred = vec![bias; n];
    let mut feat_out: Vec<u32> = Vec::with_capacity(p.n_trees * p.depth);
    let mut thr_out: Vec<f32> = Vec::with_capacity(p.n_trees * p.depth);
    let mut leaves_out: Vec<f32> = Vec::with_capacity(p.n_trees * leaves_w);

    // Per-feature candidate thresholds are data-determined once.
    let cands: Vec<Vec<f32>> = (0..n_features)
        .map(|f| candidate_thresholds(xs, f, p.n_bins))
        .collect();

    for _tree in 0..p.n_trees {
        let grad: Vec<f64> = (0..n).map(|i| pred[i] - y[i]).collect();
        let mut idx = vec![0usize; n];
        let mut tree_feat = vec![0u32; p.depth];
        let mut tree_thr = vec![f32::INFINITY; p.depth];

        for d in 0..p.depth {
            let n_leaves = 1usize << d;
            let mut leaf_g = vec![0.0f64; n_leaves];
            let mut leaf_h = vec![0.0f64; n_leaves];
            for i in 0..n {
                leaf_g[idx[i]] += grad[i];
                leaf_h[idx[i]] += 1.0;
            }
            let parent_score: f64 = (0..n_leaves)
                .map(|l| leaf_g[l] * leaf_g[l] / (leaf_h[l] + p.lambda))
                .sum();

            let mut best: Option<(f64, usize, f32)> = None;
            for f in 0..n_features {
                for &thr in &cands[f] {
                    let mut right_g = vec![0.0f64; n_leaves];
                    let mut right_h = vec![0.0f64; n_leaves];
                    for i in 0..n {
                        if xs[i][f] > thr {
                            right_g[idx[i]] += grad[i];
                            right_h[idx[i]] += 1.0;
                        }
                    }
                    let mut score = 0.0f64;
                    let mut valid = false;
                    for l in 0..n_leaves {
                        let (gl, hl) = (leaf_g[l] - right_g[l], leaf_h[l] - right_h[l]);
                        let (gr, hr) = (right_g[l], right_h[l]);
                        if hl >= p.min_child_weight && hr >= p.min_child_weight {
                            valid = true;
                            score += gl * gl / (hl + p.lambda) + gr * gr / (hr + p.lambda);
                        } else {
                            // unsplit leaf keeps parent contribution
                            let g = leaf_g[l];
                            let h = leaf_h[l];
                            score += g * g / (h + p.lambda);
                        }
                    }
                    if !valid {
                        continue;
                    }
                    let gain = score - parent_score;
                    if gain > 1e-12 && best.map(|(bg, _, _)| gain > bg).unwrap_or(true) {
                        best = Some((gain, f, thr));
                    }
                }
            }
            match best {
                Some((_, f, thr)) => {
                    tree_feat[d] = f as u32;
                    tree_thr[d] = thr;
                    for i in 0..n {
                        if xs[i][f] > thr {
                            idx[i] |= 1 << d;
                        }
                    }
                }
                None => {
                    tree_feat[d] = 0;
                    tree_thr[d] = f32::INFINITY;
                }
            }
        }

        finish_tree(
            p, n, &grad, &idx, leaves_w, &mut pred, &tree_feat, &tree_thr, &mut feat_out,
            &mut thr_out, &mut leaves_out,
        );
    }

    Ensemble {
        n_features,
        depth: p.depth,
        feat: feat_out,
        thr: thr_out,
        leaves: leaves_out,
        bias: bias as f32,
    }
}

/// FNV-1a over the exact training inputs: feature bits, target bits,
/// feature count and hyper-parameters.  Collisions are the only risk,
/// and 64-bit FNV over session-sized inputs makes them negligible;
/// the gate is an optimization, never a correctness dependency — a
/// miss just retrains.
fn training_fingerprint(
    xs: &[[f32; F_MAX]],
    y: &[f64],
    n_features: usize,
    p: &GbtParams,
) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    };
    eat(&(n_features as u64).to_le_bytes());
    eat(&(p.n_trees as u64).to_le_bytes());
    eat(&(p.depth as u64).to_le_bytes());
    eat(&p.learning_rate.to_bits().to_le_bytes());
    eat(&p.lambda.to_bits().to_le_bytes());
    eat(&(p.n_bins as u64).to_le_bytes());
    eat(&p.min_child_weight.to_bits().to_le_bytes());
    eat(&(xs.len() as u64).to_le_bytes());
    for x in xs {
        for v in x.iter() {
            eat(&v.to_bits().to_le_bytes());
        }
    }
    for v in y {
        eat(&v.to_bits().to_le_bytes());
    }
    h
}

/// Bit-exact row-prefix equality (`==` would conflate `-0.0`/`0.0`,
/// which the binned grids distinguish structurally).
fn rows_equal_bits(a: &[[f32; F_MAX]], b: &[[f32; F_MAX]]) -> bool {
    a.len() == b.len()
        && a.iter()
            .zip(b)
            .all(|(ra, rb)| ra.iter().zip(rb).all(|(x, y)| x.to_bits() == y.to_bits()))
}

/// A session-resident log-space trainer that amortizes refits:
///
/// * **Fingerprint gate** — if the exact training inputs (rows,
///   targets, hyper-parameters) match the previous call bit for bit,
///   the cached ensemble is returned without training at all (CEAL's
///   phase structure retrains on unchanged data whenever a round adds
///   only component measurements).
/// * **Incremental binning** — when the new feature rows extend the
///   previous ones (the append-only growth of a session's measured
///   set), fresh rows are merged into the retained [`BinnedDataset`]
///   via [`BinnedDataset::push_rows`] instead of re-sorting and
///   re-binning the whole set; target-only changes (winsorization,
///   outlier re-measures) retrain on the existing grid for free.
/// * Anything else — different feature count, bin budget, or a
///   non-prefix feature matrix — falls back to a full rebuild.
///
/// Every returned ensemble is **bitwise identical** to
/// `train_log(xs, y, n_features, p)` on the same inputs (push_rows'
/// rebuild-equivalence plus [`train_log_binned`]'s shared core), so
/// amortized sessions reproduce from-scratch sessions exactly.
pub struct IncrementalTrainer {
    binned: Option<BinnedDataset>,
    xs_seen: Vec<[f32; F_MAX]>,
    n_features: usize,
    bin_budget: usize,
    fp: Option<u64>,
    model: Option<Ensemble>,
    refits: u64,
    skips: u64,
    rebuilds: u64,
}

impl Default for IncrementalTrainer {
    fn default() -> Self {
        IncrementalTrainer::new()
    }
}

impl std::fmt::Debug for IncrementalTrainer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("IncrementalTrainer")
            .field("rows", &self.xs_seen.len())
            .field("refits", &self.refits)
            .field("skips", &self.skips)
            .field("rebuilds", &self.rebuilds)
            .finish()
    }
}

impl IncrementalTrainer {
    pub fn new() -> IncrementalTrainer {
        IncrementalTrainer {
            binned: None,
            xs_seen: Vec::new(),
            n_features: 0,
            bin_budget: 0,
            fp: None,
            model: None,
            refits: 0,
            skips: 0,
            rebuilds: 0,
        }
    }

    /// Amortized [`train_log`]: same signature, same (bitwise) result,
    /// per-call cost proportional to what actually changed.
    pub fn train_log(
        &mut self,
        xs: &[[f32; F_MAX]],
        y: &[f64],
        n_features: usize,
        p: &GbtParams,
    ) -> Ensemble {
        let fp = training_fingerprint(xs, y, n_features, p);
        if self.fp == Some(fp) {
            if let Some(model) = &self.model {
                self.skips += 1;
                super::ensemble::note_refit_skip();
                return model.clone();
            }
        }
        let n_prev = self.xs_seen.len();
        let extendable = self.binned.is_some()
            && self.n_features == n_features
            && self.bin_budget == p.n_bins
            && xs.len() >= n_prev
            && rows_equal_bits(&xs[..n_prev], &self.xs_seen);
        if extendable {
            if xs.len() > n_prev {
                self.binned
                    .as_mut()
                    .expect("extendable implies binned")
                    .push_rows(&xs[n_prev..]);
                self.xs_seen.extend_from_slice(&xs[n_prev..]);
            }
        } else {
            self.binned = Some(BinnedDataset::build(xs, n_features, p.n_bins));
            self.xs_seen = xs.to_vec();
            self.n_features = n_features;
            self.bin_budget = p.n_bins;
            self.rebuilds += 1;
        }
        let model =
            train_log_binned(self.binned.as_ref().expect("binned present"), y, n_features, p);
        self.refits += 1;
        self.fp = Some(fp);
        self.model = Some(model.clone());
        model
    }

    /// Trainings actually performed (gate misses).
    pub fn refits(&self) -> u64 {
        self.refits
    }

    /// Fingerprint-gated skips (cached model returned).
    pub fn skips(&self) -> u64 {
        self.skips
    }

    /// Full from-scratch re-bins (first call, or a non-append change).
    pub fn rebuilds(&self) -> u64 {
        self.rebuilds
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use crate::util::stats;

    fn make_data(
        rng: &mut Pcg32,
        n: usize,
        f: impl Fn(&[f32; F_MAX]) -> f64,
    ) -> (Vec<[f32; F_MAX]>, Vec<f64>) {
        let xs: Vec<[f32; F_MAX]> = (0..n)
            .map(|_| {
                let mut x = [0f32; F_MAX];
                for v in x.iter_mut() {
                    *v = rng.f32();
                }
                x
            })
            .collect();
        let y: Vec<f64> = xs.iter().map(&f).collect();
        (xs, y)
    }

    fn rmse(e: &Ensemble, xs: &[[f32; F_MAX]], y: &[f64]) -> f64 {
        let se: f64 = xs
            .iter()
            .zip(y)
            .map(|(x, &t)| {
                let p = e.predict(x) as f64;
                (p - t) * (p - t)
            })
            .sum();
        (se / y.len() as f64).sqrt()
    }

    #[test]
    fn fits_constant() {
        let xs = vec![[0.5f32; F_MAX]; 10];
        let y = vec![3.0; 10];
        let e = train(&xs, &y, 4, &GbtParams::default());
        assert!((e.predict(&xs[0]) - 3.0).abs() < 1e-3);
    }

    #[test]
    fn fits_step_function() {
        let mut rng = Pcg32::new(1, 0);
        let (xs, y) = make_data(&mut rng, 200, |x| if x[2] > 0.5 { 10.0 } else { 1.0 });
        let e = train(&xs, &y, 4, &GbtParams::default());
        assert!(rmse(&e, &xs, &y) < 0.5, "rmse {}", rmse(&e, &xs, &y));
    }

    #[test]
    fn fits_additive_nonlinear() {
        let mut rng = Pcg32::new(2, 0);
        let f = |x: &[f32; F_MAX]| {
            5.0 * (x[0] as f64) + 3.0 * ((x[1] as f64) - 0.5).powi(2) + (x[3] as f64).sqrt()
        };
        let (xs, y) = make_data(&mut rng, 400, f);
        let e = train(&xs, &y, 5, &GbtParams::default());
        let spread = stats::std_dev(&y);
        let err = rmse(&e, &xs, &y);
        assert!(err < spread * 0.25, "rmse {err} vs spread {spread}");
    }

    #[test]
    fn generalizes_on_holdout() {
        let mut rng = Pcg32::new(3, 0);
        let f = |x: &[f32; F_MAX]| 4.0 * (x[0] as f64) * (x[1] as f64) + 2.0 * x[2] as f64;
        let (xs, y) = make_data(&mut rng, 500, f);
        let (tx, ty) = make_data(&mut rng, 200, f);
        let e = train(&xs, &y, 4, &GbtParams::default());
        let err = rmse(&e, &tx, &ty);
        let spread = stats::std_dev(&ty);
        assert!(err < spread * 0.4, "holdout rmse {err} vs spread {spread}");
    }

    #[test]
    fn small_sample_budget_works() {
        // 25 samples, the paper's smallest budget — must not blow up.
        let mut rng = Pcg32::new(4, 0);
        let f = |x: &[f32; F_MAX]| 100.0 * x[0] as f64 + 10.0;
        let (xs, y) = make_data(&mut rng, 25, f);
        let e = train(&xs, &y, 3, &GbtParams::small_data());
        // monotone recovery: predictions correlate with x0
        let lo = e.predict(&{
            let mut v = [0.5f32; F_MAX];
            v[0] = 0.05;
            v
        });
        let hi = e.predict(&{
            let mut v = [0.5f32; F_MAX];
            v[0] = 0.95;
            v
        });
        assert!(hi > lo + 20.0, "lo {lo} hi {hi}");
    }

    #[test]
    fn flattened_matches_native_after_training() {
        let mut rng = Pcg32::new(5, 0);
        let f = |x: &[f32; F_MAX]| (x[0] as f64) * 7.0 - (x[1] as f64) * 2.0;
        let (xs, y) = make_data(&mut rng, 150, f);
        let e = train(&xs, &y, 4, &GbtParams::default());
        let flat = e.flatten();
        for x in xs.iter().take(40) {
            let a = e.predict(x);
            let b = flat.predict(x);
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn degenerate_inputs() {
        for engine in [train as fn(&[[f32; F_MAX]], &[f64], usize, &GbtParams) -> Ensemble, train_exact] {
            // empty
            let e = engine(&[], &[], 2, &GbtParams::default());
            assert_eq!(e.predict(&[0.0; F_MAX]), 0.0);
            // single sample
            let e1 = engine(&[[0.1; F_MAX]], &[5.0], 2, &GbtParams::default());
            assert!((e1.predict(&[0.9; F_MAX]) - 5.0).abs() < 1e-6);
        }
    }

    #[test]
    fn deterministic() {
        let mut rng = Pcg32::new(6, 0);
        let (xs, y) = make_data(&mut rng, 60, |x| x[0] as f64);
        let a = train(&xs, &y, 2, &GbtParams::default());
        let b = train(&xs, &y, 2, &GbtParams::default());
        assert_eq!(a, b);
    }

    #[test]
    fn exact_engine_deterministic() {
        let mut rng = Pcg32::new(6, 1);
        let (xs, y) = make_data(&mut rng, 60, |x| x[0] as f64);
        let a = train_exact(&xs, &y, 2, &GbtParams::default());
        let b = train_exact(&xs, &y, 2, &GbtParams::default());
        assert_eq!(a, b);
    }

    #[test]
    fn histogram_engine_tracks_exact_engine() {
        // Same candidate sets and tie-breaks: in-sample fits of the two
        // engines must be statistically indistinguishable (they may
        // pick different near-tied splits only through last-bit f64
        // rounding of the gradient sums — the histogram engine folds
        // leaf totals in bin order, the exact engine in row order).
        let mut rng = Pcg32::new(7, 0);
        let f = |x: &[f32; F_MAX]| {
            20.0 * (x[0] as f64) + 8.0 * (x[1] as f64) * (x[2] as f64)
                - 5.0 * ((x[3] as f64) - 0.4).powi(2)
        };
        for n in [30usize, 120, 400] {
            let (xs, y) = make_data(&mut rng, n, f);
            let (tx, ty) = make_data(&mut rng, 150, f);
            for params in [GbtParams::default(), GbtParams::small_data()] {
                let h = train(&xs, &y, 5, &params);
                let e = train_exact(&xs, &y, 5, &params);
                let (rh, re) = (rmse(&h, &tx, &ty), rmse(&e, &tx, &ty));
                let spread = stats::std_dev(&ty);
                assert!(
                    (rh - re).abs() <= 0.05 * spread + 1e-9,
                    "n={n}: hist rmse {rh} vs exact rmse {re} (spread {spread})"
                );
            }
        }
    }

    fn assert_ensembles_bitwise(a: &Ensemble, b: &Ensemble, label: &str) {
        assert_eq!(a.n_features, b.n_features, "{label}: n_features");
        assert_eq!(a.depth, b.depth, "{label}: depth");
        assert_eq!(a.feat, b.feat, "{label}: feat");
        assert_eq!(a.bias.to_bits(), b.bias.to_bits(), "{label}: bias");
        assert_eq!(a.thr.len(), b.thr.len(), "{label}: thr len");
        for (i, (x, y)) in a.thr.iter().zip(&b.thr).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{label}: thr[{i}]");
        }
        assert_eq!(a.leaves.len(), b.leaves.len(), "{label}: leaves len");
        for (i, (x, y)) in a.leaves.iter().zip(&b.leaves).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{label}: leaves[{i}]");
        }
    }

    #[test]
    fn incremental_trainer_matches_train_log_bitwise() {
        // Randomized append schedules: each call extends the previous
        // rows by 0..30 new ones (sometimes with repeated coarse
        // values, sometimes signed zeros), and the amortized trainer
        // must reproduce from-scratch train_log bit for bit.
        let mut rng = Pcg32::new(0xA11CE, 9);
        for trial in 0..6u32 {
            let nf = 2 + (rng.next_u32() % 4) as usize;
            let p = if trial % 2 == 0 { GbtParams::small_data() } else { GbtParams::default() };
            let mut tr = IncrementalTrainer::new();
            let mut xs: Vec<[f32; F_MAX]> = Vec::new();
            let mut y: Vec<f64> = Vec::new();
            for step in 0..5u32 {
                let add = (rng.next_u32() % 31) as usize;
                for _ in 0..add {
                    let mut x = [0f32; F_MAX];
                    for v in x.iter_mut().take(nf) {
                        let lattice = (rng.next_u32() % 17) as f32 / 8.0 - 1.0;
                        *v = if rng.next_u32() % 7 == 0 { -0.0 } else { lattice };
                    }
                    let t = 3.0 + 2.0 * x[0] as f64 - x[1] as f64;
                    xs.push(x);
                    y.push(t.exp());
                }
                let inc = tr.train_log(&xs, &y, nf, &p);
                let scratch = train_log(&xs, &y, nf, &p);
                assert_ensembles_bitwise(&inc, &scratch, &format!("trial={trial} step={step}"));
            }
            assert_eq!(tr.rebuilds(), 1, "trial={trial}: only the first call re-bins");
        }
    }

    #[test]
    fn incremental_trainer_skips_identical_inputs() {
        let mut rng = Pcg32::new(42, 1);
        let (xs, y0) = make_data(&mut rng, 60, |x| (1.0 + x[0] as f64).exp());
        let y: Vec<f64> = y0.iter().map(|v| v.max(1e-9)).collect();
        let p = GbtParams::small_data();
        let mut tr = IncrementalTrainer::new();
        let a = tr.train_log(&xs, &y, 3, &p);
        assert_eq!((tr.refits(), tr.skips()), (1, 0));
        let b = tr.train_log(&xs, &y, 3, &p);
        assert_eq!((tr.refits(), tr.skips()), (1, 1), "identical inputs skip training");
        assert_ensembles_bitwise(&a, &b, "skip returns the cached model");

        // Target-only change: retrains (no skip) but keeps the binned
        // grid — no rebuild.
        let y2: Vec<f64> = y.iter().map(|v| v * 1.5).collect();
        let c = tr.train_log(&xs, &y2, 3, &p);
        assert_eq!((tr.refits(), tr.skips(), tr.rebuilds()), (2, 1, 1));
        assert_ensembles_bitwise(&c, &train_log(&xs, &y2, 3, &p), "y-only change");

        // Changed hyper-parameters (bin budget) force a full rebuild.
        let mut p2 = p.clone();
        p2.n_bins = p.n_bins / 2;
        let d = tr.train_log(&xs, &y2, 3, &p2);
        assert_eq!(tr.rebuilds(), 2, "bin-budget change re-bins");
        assert_ensembles_bitwise(&d, &train_log(&xs, &y2, 3, &p2), "params change");

        // A non-prefix feature change (mutated first row) also rebuilds.
        let mut xs2 = xs.clone();
        xs2[0][0] += 0.25;
        let e = tr.train_log(&xs2, &y2, 3, &p2);
        assert_eq!(tr.rebuilds(), 3, "mutated prefix re-bins");
        assert_ensembles_bitwise(&e, &train_log(&xs2, &y2, 3, &p2), "prefix change");
    }
}
