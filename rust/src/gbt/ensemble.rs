//! Flattened oblivious-tree ensembles and the native predictor.
//!
//! The flattened layout must match the AOT artifacts bit-for-bit in
//! semantics (see python/compile/kernels/gbt_predict.py):
//!
//! * `feat[t*D + d]` — feature tested by tree `t` at level `d`
//! * `thr[t*D + d]` — threshold; strict `>` sends the sample right
//! * `leaves[t*2^D + idx]` — leaf value, `idx = Σ_d (x[f_d] > t_d) << d`
//!
//! Padding conventions: unused trees carry `thr = +inf`, `leaves = 0`;
//! the ensemble bias is folded into tree 0's leaves at flatten time.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::config::F_MAX;
use crate::util::parallel;

/// Artifact-side maxima (python/compile/kernels/gbt_predict.py).
pub const TREES_MAX: usize = 64;
pub const DEPTH_MAX: usize = 6;
pub const LEAVES_MAX: usize = 1 << DEPTH_MAX;

/// Log-space prediction assigned to padding components in the lowfi
/// artifact: exp(NEG_PRED) == 0, neutral under max-of-times and sum.
pub const NEG_PRED: f32 = -1.0e9;

/// Row-block width of the batched native predictors: small enough for
/// a block's feature rows plus leaf indices to stay L1-resident, large
/// enough to amortize each tree's (feature, threshold) loads.
pub const PREDICT_BLOCK: usize = 64;

/// Below this many rows `predict_batch` stays row-at-a-time: no block
/// buffers, no fork-join hand-off — the per-batch single-config path
/// of the tuners' inner loops must not pay batch-dispatch setup.
pub const PREDICT_SMALL: usize = 16;

/// Rows needed before `predict_batch` shards row blocks across the
/// worker pool (below it one thread saturates the memory system).
const PREDICT_PAR_ROWS: usize = 512;

/// Rows per parallel task — a multiple of [`PREDICT_BLOCK`], fixed so
/// chunk boundaries (and therefore results) never depend on the
/// worker count.
const PREDICT_CHUNK: usize = 128;

/// Pool size at which scoring routes through [`QuantizedEnsemble`]:
/// below it the pre-coding pass costs more than it saves; above it
/// the integer-compare traversal over cache-resident code columns
/// wins.  Legacy pools (≤2000 configs) never cross it, so every
/// historical bitwise pin keeps exercising the dense-float path.
pub const QUANTIZE_MIN_ROWS: usize = 4096;

/// A trained oblivious-GBT ensemble (compact, depth = `depth`).
#[derive(Clone, Debug, PartialEq)]
pub struct Ensemble {
    pub n_features: usize,
    pub depth: usize,
    /// Per-tree level features, `[n_trees * depth]`.
    pub feat: Vec<u32>,
    /// Per-tree level thresholds, `[n_trees * depth]`.
    pub thr: Vec<f32>,
    /// Per-tree leaf tables, `[n_trees * 2^depth]`.
    pub leaves: Vec<f32>,
    /// Additive bias (mean response), applied once per prediction.
    pub bias: f32,
}

impl Ensemble {
    /// A bias-only ensemble (predicts a constant).
    pub fn constant(n_features: usize, bias: f32) -> Self {
        Ensemble {
            n_features,
            depth: 1,
            feat: Vec::new(),
            thr: Vec::new(),
            leaves: Vec::new(),
            bias,
        }
    }

    pub fn n_trees(&self) -> usize {
        if self.depth == 0 {
            0
        } else {
            self.feat.len() / self.depth
        }
    }

    /// Leaf index of `x` in tree `t` — the kernel's bit-packing rule.
    #[inline]
    pub fn leaf_index(&self, t: usize, x: &[f32]) -> usize {
        let mut idx = 0usize;
        for d in 0..self.depth {
            let f = self.feat[t * self.depth + d] as usize;
            let thr = self.thr[t * self.depth + d];
            if x[f] > thr {
                idx |= 1 << d;
            }
        }
        idx
    }

    /// Predict a single feature vector (length >= n_features).
    pub fn predict(&self, x: &[f32]) -> f32 {
        let leaves_w = 1 << self.depth;
        let mut acc = self.bias;
        for t in 0..self.n_trees() {
            acc += self.leaves[t * leaves_w + self.leaf_index(t, x)];
        }
        acc
    }

    /// Predict a batch of F_MAX-padded rows.
    ///
    /// Tree-major blocked evaluation: rows are processed in blocks of
    /// [`PREDICT_BLOCK`], and within a block each tree's per-level
    /// (feature, threshold) pair is loaded once and applied across the
    /// whole block — the structure-of-arrays hot path used for
    /// campaign-scale pool scoring.  Pool-sized batches additionally
    /// shard fixed [`PREDICT_CHUNK`]-row chunks across the worker pool
    /// (each chunk has one writer), while batches under
    /// [`PREDICT_SMALL`] skip block and dispatch setup entirely.  Per
    /// row, the accumulation order (bias, then trees ascending) is
    /// identical to [`Self::predict`] on every path, so results match
    /// the row-at-a-time predictor bit for bit at any batch size and
    /// worker count.
    pub fn predict_batch(&self, xs: &[[f32; F_MAX]]) -> Vec<f32> {
        let n = xs.len();
        if n < PREDICT_SMALL {
            // small-batch fast path: single-config scoring calls
            return xs.iter().map(|x| self.predict(x)).collect();
        }
        let mut out = vec![self.bias; n];
        let width = parallel::width_for(n, PREDICT_PAR_ROWS);
        parallel::for_each_chunk_mut(width, PREDICT_CHUNK, &mut out, |ci, acc| {
            let start = ci * PREDICT_CHUNK;
            self.predict_blocked(&xs[start..start + acc.len()], acc);
        });
        out
    }

    /// Tree-major blocked evaluation of `rows_all` into `acc_all`
    /// (pre-seeded with the bias) — the kernel [`Self::predict_batch`]
    /// runs per chunk.
    fn predict_blocked(&self, rows_all: &[[f32; F_MAX]], acc_all: &mut [f32]) {
        let n_trees = self.n_trees();
        let leaves_w = 1usize << self.depth;
        let mut leaf_idx = [0usize; PREDICT_BLOCK];
        for (rows, acc) in rows_all
            .chunks(PREDICT_BLOCK)
            .zip(acc_all.chunks_mut(PREDICT_BLOCK))
        {
            for t in 0..n_trees {
                let base = t * self.depth;
                leaf_idx[..rows.len()].fill(0);
                for d in 0..self.depth {
                    let f = self.feat[base + d] as usize;
                    let thr = self.thr[base + d];
                    let bit = 1usize << d;
                    for (li, row) in leaf_idx.iter_mut().zip(rows) {
                        if row[f] > thr {
                            *li |= bit;
                        }
                    }
                }
                let leaves = &self.leaves[t * leaves_w..(t + 1) * leaves_w];
                for (a, &li) in acc.iter_mut().zip(leaf_idx.iter()) {
                    *a += leaves[li];
                }
            }
        }
    }

    /// Flatten to artifact shape `[TREES_MAX, DEPTH_MAX]` /
    /// `[TREES_MAX, LEAVES_MAX]`, folding the bias into tree 0.
    pub fn flatten(&self) -> FlatEnsemble {
        assert!(
            self.n_trees() <= TREES_MAX,
            "{} trees exceed artifact capacity {TREES_MAX}",
            self.n_trees()
        );
        assert!(
            self.depth <= DEPTH_MAX,
            "depth {} exceeds artifact depth {DEPTH_MAX}",
            self.depth
        );
        let mut feat = vec![0i32; TREES_MAX * DEPTH_MAX];
        let mut thr = vec![f32::INFINITY; TREES_MAX * DEPTH_MAX];
        let mut leaves = vec![0f32; TREES_MAX * LEAVES_MAX];
        let my_leaves = 1 << self.depth;
        for t in 0..self.n_trees() {
            for d in 0..self.depth {
                feat[t * DEPTH_MAX + d] = self.feat[t * self.depth + d] as i32;
                thr[t * DEPTH_MAX + d] = self.thr[t * self.depth + d];
            }
            // levels beyond self.depth keep +inf thresholds -> bit 0,
            // so the effective leaf index equals the compact index.
            for idx in 0..my_leaves {
                leaves[t * LEAVES_MAX + idx] = self.leaves[t * my_leaves + idx];
            }
        }
        // Fold bias into tree 0 (tree 0 always exists in the artifact:
        // if the ensemble is empty, it is a pure constant tree).
        for idx in 0..LEAVES_MAX {
            if self.n_trees() == 0 {
                leaves[idx] = self.bias;
            } else if idx < my_leaves {
                leaves[idx] += self.bias;
            }
        }
        if self.n_trees() == 0 {
            // make every input land on a defined leaf value
            for v in leaves.iter_mut().take(LEAVES_MAX) {
                *v = self.bias;
            }
        }
        FlatEnsemble { feat, thr, leaves }
    }
}

/// Artifact-shaped ensemble tensors (runtime inputs to the HLO).
#[derive(Clone, Debug, PartialEq)]
pub struct FlatEnsemble {
    /// `[TREES_MAX * DEPTH_MAX]` i32
    pub feat: Vec<i32>,
    /// `[TREES_MAX * DEPTH_MAX]` f32
    pub thr: Vec<f32>,
    /// `[TREES_MAX * LEAVES_MAX]` f32
    pub leaves: Vec<f32>,
}

impl FlatEnsemble {
    /// All-padding ensemble predicting exactly 0 (neutral for a raw,
    /// non-exponentiated scoring path).
    pub fn zero() -> Self {
        FlatEnsemble {
            feat: vec![0; TREES_MAX * DEPTH_MAX],
            thr: vec![f32::INFINITY; TREES_MAX * DEPTH_MAX],
            leaves: vec![0.0; TREES_MAX * LEAVES_MAX],
        }
    }

    /// Padding-component ensemble for the lowfi artifact: predicts
    /// [`NEG_PRED`] so exp(prediction) == 0 (neutral component slot).
    pub fn neutral_component() -> Self {
        let mut f = FlatEnsemble::zero();
        for idx in 0..LEAVES_MAX {
            f.leaves[idx] = NEG_PRED;
        }
        f
    }

    /// Reference evaluation of the flattened format (mirrors ref.py);
    /// used to cross-check the PJRT path in integration tests.
    pub fn predict(&self, x: &[f32]) -> f32 {
        let mut acc = 0.0f32;
        for t in 0..TREES_MAX {
            let mut idx = 0usize;
            for d in 0..DEPTH_MAX {
                let f = self.feat[t * DEPTH_MAX + d] as usize;
                if x[f] > self.thr[t * DEPTH_MAX + d] {
                    idx |= 1 << d;
                }
            }
            acc += self.leaves[t * LEAVES_MAX + idx];
        }
        acc
    }

    /// Batched evaluation of the flattened format, blocked like
    /// [`Ensemble::predict_batch`] and sharded across the worker pool
    /// at pool scale (batches under [`PREDICT_SMALL`] go row-at-a-time
    /// with no dispatch setup).  Trailing padding trees — leaf tables
    /// that are identically zero — contribute exactly 0 per row and
    /// are skipped on the blocked path, so each result equals
    /// [`Self::predict`] on the same row (`==`; only a `-0.0`/`+0.0`
    /// sign can differ) at any batch size and worker count.
    pub fn predict_batch(&self, xs: &[[f32; F_MAX]]) -> Vec<f32> {
        let n = xs.len();
        if n < PREDICT_SMALL {
            return xs.iter().map(|x| self.predict(x)).collect();
        }
        let n_active = (0..TREES_MAX)
            .rev()
            .find(|&t| {
                self.leaves[t * LEAVES_MAX..(t + 1) * LEAVES_MAX]
                    .iter()
                    .any(|&v| v != 0.0)
            })
            .map_or(0, |t| t + 1);
        let mut out = vec![0.0f32; n];
        let width = parallel::width_for(n, PREDICT_PAR_ROWS);
        parallel::for_each_chunk_mut(width, PREDICT_CHUNK, &mut out, |ci, acc| {
            let start = ci * PREDICT_CHUNK;
            self.predict_blocked(n_active, &xs[start..start + acc.len()], acc);
        });
        out
    }

    /// Blocked kernel of [`Self::predict_batch`], evaluating the first
    /// `n_active` trees of `rows_all` into zero-seeded `acc_all`.
    fn predict_blocked(&self, n_active: usize, rows_all: &[[f32; F_MAX]], acc_all: &mut [f32]) {
        let mut leaf_idx = [0usize; PREDICT_BLOCK];
        for (rows, acc) in rows_all
            .chunks(PREDICT_BLOCK)
            .zip(acc_all.chunks_mut(PREDICT_BLOCK))
        {
            for t in 0..n_active {
                let base = t * DEPTH_MAX;
                leaf_idx[..rows.len()].fill(0);
                for d in 0..DEPTH_MAX {
                    let f = self.feat[base + d] as usize;
                    let thr = self.thr[base + d];
                    let bit = 1usize << d;
                    for (li, row) in leaf_idx.iter_mut().zip(rows) {
                        if row[f] > thr {
                            *li |= bit;
                        }
                    }
                }
                let leaves = &self.leaves[t * LEAVES_MAX..(t + 1) * LEAVES_MAX];
                for (a, &li) in acc.iter_mut().zip(leaf_idx.iter()) {
                    *a += leaves[li];
                }
            }
        }
    }
}

/// Column-major pool feature codes.  Ensemble-owned grids (`build`)
/// need `u8` or `u16` (node counts cap cuts at `TREES_MAX * DEPTH_MAX
/// = 384`); pool-resident grids ([`PoolCodes`]) rank against every
/// distinct column value, so a `u32` lane covers pools whose columns
/// exceed 65 535 uniques.
enum Codes {
    U8(Vec<u8>),
    U16(Vec<u16>),
    U32(Vec<u32>),
}

impl Codes {
    fn byte_len(&self) -> usize {
        match self {
            Codes::U8(v) => v.len(),
            Codes::U16(v) => v.len() * 2,
            Codes::U32(v) => v.len() * 4,
        }
    }
}

/// Process-lifetime amortization counters: how often the pool was
/// coded from scratch, how often a refit only re-ranked thresholds
/// into an existing grid, how often the legacy full `build` ran, and
/// how many session refits were skipped by the training-set
/// fingerprint gate.  Printed by `ceal tune` / `ceal info` and
/// asserted by the CI amortization cell.
static POOL_CODE_BUILDS: AtomicU64 = AtomicU64::new(0);
static QUANT_RERANKS: AtomicU64 = AtomicU64::new(0);
static QUANT_FULL_BUILDS: AtomicU64 = AtomicU64::new(0);
static REFIT_SKIPS: AtomicU64 = AtomicU64::new(0);

/// Snapshot of the process-wide amortization counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AmortCounters {
    /// Full O(pool · F) [`PoolCodes::build`] passes.
    pub pool_code_builds: u64,
    /// O(trees · depth · log uniques) [`QuantizedEnsemble::rerank`]s.
    pub quant_reranks: u64,
    /// Legacy per-call [`QuantizedEnsemble::build`]s (O(pool · used)).
    pub quant_full_builds: u64,
    /// Session refits skipped by the training-set fingerprint gate.
    pub refit_skips: u64,
}

/// Read the process-wide amortization counters.
pub fn amortization_counters() -> AmortCounters {
    AmortCounters {
        pool_code_builds: POOL_CODE_BUILDS.load(Ordering::Relaxed),
        quant_reranks: QUANT_RERANKS.load(Ordering::Relaxed),
        quant_full_builds: QUANT_FULL_BUILDS.load(Ordering::Relaxed),
        refit_skips: REFIT_SKIPS.load(Ordering::Relaxed),
    }
}

/// Count one fingerprint-gated refit skip (see `gbt::IncrementalTrainer`).
pub(crate) fn note_refit_skip() {
    REFIT_SKIPS.fetch_add(1, Ordering::Relaxed);
}

/// Pool-resident feature codes: every feature column of a candidate
/// pool ranked once against its own sorted distinct values, so that
/// *any* ensemble refit can be quantized against the pool by merely
/// re-ranking its thresholds into the fixed grid
/// ([`QuantizedEnsemble::rerank`]) — no O(pool) work per refit.
///
/// Per column the grid is the ascending list of distinct finite values
/// (`f32::total_cmp` sort, numeric `==` dedup merges `-0.0`/`0.0`,
/// NaNs excluded).  A row's code is `#{u : u < x} + 1` for finite `x`
/// and `0` for NaN; a threshold's rank is `#{u : u ≤ thr}` (sentinel
/// `uniques.len()` for NaN).  Then for every pool row
///
/// ```text
/// x > thr  ⟺  code(x) > rank(thr)
/// ```
///
/// — if `x > thr`, every unique ≤ thr is < x, so
/// `#{u < x} ≥ #{u ≤ thr}`; if `x ≤ thr`, `x` itself is a unique
/// counted by `≤ thr` but not by `< x`, so `code(x) ≤ rank(thr)`.  NaN
/// rows code to 0 and fall left everywhere (as `NaN > thr` is false);
/// NaN thresholds rank to the sentinel no code exceeds (as `x > NaN`
/// is false).  Leaf selection after re-ranking is therefore identical
/// to [`Ensemble::leaf_index`], bit for bit.
///
/// All `F_MAX` columns are coded (column-major, stride = `n_rows`) so
/// node feature indices address code columns directly; one lane width
/// serves the whole pool (`u8`/`u16`/`u32` by the largest per-column
/// unique count).
pub struct PoolCodes {
    n_rows: usize,
    /// Per feature column: ascending deduplicated finite values.
    uniques: Vec<Vec<f32>>,
    /// Column-major rank codes, `[F_MAX * n_rows]`.
    codes: Codes,
}

impl PoolCodes {
    /// Rank-code every feature column of `xs`.  O(pool · F · log pool)
    /// — paid **once per (pool, scorer)**, not per refit.
    pub fn build(xs: &[[f32; F_MAX]]) -> PoolCodes {
        let n_rows = xs.len();
        let uniques: Vec<Vec<f32>> = (0..F_MAX)
            .map(|f| {
                let mut vals: Vec<f32> =
                    xs.iter().map(|row| row[f]).filter(|v| !v.is_nan()).collect();
                vals.sort_unstable_by(f32::total_cmp);
                vals.dedup();
                vals
            })
            .collect();
        let max_code = uniques.iter().map(Vec::len).max().unwrap_or(0);
        // One coding task per column (chunk size = n_rows aligns each
        // `for_each_chunk_mut` chunk with exactly one code column).
        let width = parallel::width_for(n_rows.saturating_mul(F_MAX), PREDICT_PAR_ROWS);
        macro_rules! code_lane {
            ($ty:ty) => {{
                let mut codes = vec![0 as $ty; F_MAX * n_rows];
                parallel::for_each_chunk_mut(width, n_rows.max(1), &mut codes, |f, slice| {
                    let u = &uniques[f];
                    for (r, c) in slice.iter_mut().enumerate() {
                        let x = xs[r][f];
                        *c = if x.is_nan() {
                            0
                        } else {
                            (u.partition_point(|&v| v < x) + 1) as $ty
                        };
                    }
                });
                codes
            }};
        }
        let codes = if max_code <= u8::MAX as usize {
            Codes::U8(code_lane!(u8))
        } else if max_code <= u16::MAX as usize {
            Codes::U16(code_lane!(u16))
        } else {
            Codes::U32(code_lane!(u32))
        };
        POOL_CODE_BUILDS.fetch_add(1, Ordering::Relaxed);
        PoolCodes {
            n_rows,
            uniques,
            codes,
        }
    }

    /// Rank of threshold `thr` in column `f`'s grid: `#{u : u ≤ thr}`,
    /// with the NaN sentinel `uniques.len()` that no code exceeds.
    pub fn rank_of(&self, f: usize, thr: f32) -> u32 {
        let u = &self.uniques[f];
        if thr.is_nan() {
            u.len() as u32
        } else {
            u.partition_point(|&v| v <= thr) as u32
        }
    }

    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    /// Approximate resident bytes (code lanes + unique grids).
    pub fn approx_bytes(&self) -> usize {
        self.codes.byte_len() + self.uniques.iter().map(|u| u.len() * 4).sum::<usize>()
    }
}

/// Where a [`QuantizedEnsemble`]'s code columns live: owned (built
/// per call against the ensemble's own cut grid) or shared with a
/// pool-resident [`PoolCodes`] (built once per pool, re-used by every
/// refit's re-rank).
enum CodeStore {
    Owned(Codes),
    Shared(Arc<PoolCodes>),
}

/// A pool-quantized view of one [`Ensemble`]: the same binning idea as
/// `hist::BinnedDataset` (a row's code per column = number of candidate
/// cuts strictly below its value) applied to *scoring* instead of
/// training.
///
/// Two construction routes share one traversal kernel: `build`
/// pre-codes the pool's feature columns against the ensemble's own
/// thresholds (sorted, deduplicated cut lists per used feature), while
/// `rerank` borrows a pool-resident [`PoolCodes`] grid and only ranks
/// the ensemble's thresholds into it — O(trees · depth · log uniques)
/// per refit instead of O(pool · F).  Either way tree traversal is
/// pure integer compares over flat column-major code arrays
/// (`codes[col * n_rows + row]`), with thresholds stored as cut ranks
/// and leaf tables as the ensemble's flat f32 arrays.  One narrow
/// integer lane per row per coded feature is cache-resident at 10^6
/// rows where the dense `[f32; F_MAX]` rows are not, and the inner
/// loop (`code > cut_rank`) auto-vectorizes.
///
/// ## Exactness contract
///
/// For ascending deduplicated cuts, `code(x) = #{k : x > cut_k}`
/// satisfies `x > cut_r ⟺ code(x) > r` for every node rank `r`
/// (NaN feature values code to 0 and fall left everywhere, exactly as
/// `NaN > thr` is false; NaN thresholds get the sentinel rank
/// `cuts.len()`, which no code exceeds, exactly as `x > NaN` is
/// false).  Leaf selection is therefore *identical* to
/// [`Ensemble::leaf_index`], and the accumulation order (bias seed,
/// then trees ascending) matches [`Ensemble::predict_batch`], so
/// predictions are **bitwise equal** to `predict_batch` — and
/// bin-boundary-consistent with [`FlatEnsemble::predict_batch`] to the
/// same tolerance `predict_batch` itself is.  Differential tests pin
/// both.
pub struct QuantizedEnsemble {
    n_rows: usize,
    depth: usize,
    n_trees: usize,
    bias: f32,
    codes: CodeStore,
    /// Per-node code-column index, `[n_trees * depth]`.
    node_col: Vec<u32>,
    /// Per-node cut rank (the quantized threshold), `[n_trees * depth]`.
    node_cut: Vec<u32>,
    /// Flat leaf tables, `[n_trees * 2^depth]` (copied from the ensemble).
    leaves: Vec<f32>,
}

impl QuantizedEnsemble {
    /// Pre-code `xs` against `ens`'s thresholds.  O(n · used_features ·
    /// log cuts) — the from-scratch reference path.  On the refit loop
    /// prefer [`Self::rerank`] against a cached [`PoolCodes`]: the
    /// pool is coded **once per (pool, scorer)** and each refit pays
    /// only O(trees · depth · log uniques) to re-rank its thresholds,
    /// with bitwise-identical predictions.
    pub fn build(ens: &Ensemble, xs: &[[f32; F_MAX]]) -> QuantizedEnsemble {
        QUANT_FULL_BUILDS.fetch_add(1, Ordering::Relaxed);
        let n_rows = xs.len();
        let n_trees = ens.n_trees();
        let n_nodes = n_trees * ens.depth;
        // Used feature set, in ascending feature order.
        let mut used: Vec<u32> = ens.feat[..n_nodes].to_vec();
        used.sort_unstable();
        used.dedup();
        // Per used feature: ascending deduplicated finite cut list.
        // f32 `==` dedup merges -0.0/0.0 (identical `>` predicates);
        // NaN thresholds are excluded and handled by sentinel rank.
        let cuts_per_col: Vec<Vec<f32>> = used
            .iter()
            .map(|&f| {
                let mut cuts: Vec<f32> = (0..n_nodes)
                    .filter(|&i| ens.feat[i] == f && !ens.thr[i].is_nan())
                    .map(|i| ens.thr[i])
                    .collect();
                cuts.sort_unstable_by(f32::total_cmp);
                cuts.dedup();
                cuts
            })
            .collect();
        let node_col: Vec<u32> = ens.feat[..n_nodes]
            .iter()
            .map(|f| used.binary_search(f).expect("used feature") as u32)
            .collect();
        let node_cut: Vec<u32> = (0..n_nodes)
            .map(|i| {
                let cuts = &cuts_per_col[node_col[i] as usize];
                let thr = ens.thr[i];
                if thr.is_nan() {
                    cuts.len() as u32 // `x > NaN` is never true
                } else {
                    cuts.iter().position(|&c| c == thr).expect("cut present") as u32
                }
            })
            .collect();
        let max_cuts = cuts_per_col.iter().map(Vec::len).max().unwrap_or(0);
        // One coding task per column: chunk size = n_rows aligns each
        // `for_each_chunk_mut` chunk with exactly one code column.
        let width = parallel::width_for(n_rows.saturating_mul(used.len()), PREDICT_PAR_ROWS);
        let code_col = |codes: &mut [u16], col: usize| {
            let f = used[col] as usize;
            let cuts = &cuts_per_col[col];
            for (r, c) in codes.iter_mut().enumerate() {
                *c = cuts.partition_point(|&t| xs[r][f] > t) as u16;
            }
        };
        let codes = if max_cuts <= u8::MAX as usize {
            let mut codes = vec![0u8; used.len() * n_rows];
            parallel::for_each_chunk_mut(width, n_rows.max(1), &mut codes, |col, slice| {
                let f = used[col] as usize;
                let cuts = &cuts_per_col[col];
                for (r, c) in slice.iter_mut().enumerate() {
                    *c = cuts.partition_point(|&t| xs[r][f] > t) as u8;
                }
            });
            Codes::U8(codes)
        } else {
            let mut codes = vec![0u16; used.len() * n_rows];
            parallel::for_each_chunk_mut(width, n_rows.max(1), &mut codes, |col, slice| {
                code_col(slice, col)
            });
            Codes::U16(codes)
        };
        QuantizedEnsemble {
            n_rows,
            depth: ens.depth,
            n_trees,
            bias: ens.bias,
            codes: CodeStore::Owned(codes),
            node_col,
            node_cut,
            leaves: ens.leaves.clone(),
        }
    }

    /// Quantize `ens` against an existing pool grid: re-rank every
    /// node threshold into `pool`'s per-column unique arrays.
    /// O(trees · depth · log uniques) — **no O(pool) work** — and the
    /// [`PoolCodes`] exactness contract makes predictions bitwise
    /// equal to [`Self::build`] over the same rows.
    pub fn rerank(ens: &Ensemble, pool: &Arc<PoolCodes>) -> QuantizedEnsemble {
        let n_trees = ens.n_trees();
        let n_nodes = n_trees * ens.depth;
        // Shared grids code all F_MAX columns, so node columns are the
        // raw feature indices — no used-feature compaction needed.
        let node_col: Vec<u32> = ens.feat[..n_nodes].to_vec();
        let node_cut: Vec<u32> = (0..n_nodes)
            .map(|i| pool.rank_of(ens.feat[i] as usize, ens.thr[i]))
            .collect();
        QUANT_RERANKS.fetch_add(1, Ordering::Relaxed);
        QuantizedEnsemble {
            n_rows: pool.n_rows,
            depth: ens.depth,
            n_trees,
            bias: ens.bias,
            codes: CodeStore::Shared(Arc::clone(pool)),
            node_col,
            node_cut,
            leaves: ens.leaves.clone(),
        }
    }

    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    /// Approximate resident bytes of the coded pool (for cache
    /// accounting).  Shared pool grids are accounted once on the cache
    /// side ([`PoolCodes::approx_bytes`]), not per re-ranked view.
    pub fn approx_bytes(&self) -> usize {
        let code_bytes = match &self.codes {
            CodeStore::Owned(codes) => codes.byte_len(),
            CodeStore::Shared(_) => 0,
        };
        code_bytes
            + self.node_col.len() * 4
            + self.node_cut.len() * 4
            + self.leaves.len() * 4
    }

    /// Predict every pooled row — bitwise equal to
    /// `Ensemble::predict_batch` over the rows `build` coded.  Fixed
    /// [`PREDICT_CHUNK`]-row chunks shard across the worker pool (one
    /// writer per chunk), so results are worker-count-invariant.
    pub fn predict_all(&self) -> Vec<f32> {
        let mut out = vec![self.bias; self.n_rows];
        let width = parallel::width_for(self.n_rows, PREDICT_PAR_ROWS);
        parallel::for_each_chunk_mut(width, PREDICT_CHUNK, &mut out, |ci, acc| {
            self.predict_block(ci * PREDICT_CHUNK, acc);
        });
        out
    }

    /// Predict the row range `[start, start + acc.len())` into `acc` —
    /// the per-chunk form `Scorer::score_fold` streams through without
    /// materializing an O(pool) vector.
    pub fn predict_range_into(&self, start: usize, acc: &mut [f32]) {
        assert!(start + acc.len() <= self.n_rows, "range beyond coded pool");
        acc.fill(self.bias);
        self.predict_block(start, acc);
    }

    fn predict_block(&self, start: usize, acc_all: &mut [f32]) {
        let codes = match &self.codes {
            CodeStore::Owned(codes) => codes,
            CodeStore::Shared(pool) => &pool.codes,
        };
        match codes {
            Codes::U8(c) => self.predict_block_t(c, |r| r as u8, start, acc_all),
            Codes::U16(c) => self.predict_block_t(c, |r| r as u16, start, acc_all),
            Codes::U32(c) => self.predict_block_t(c, |r| r, start, acc_all),
        }
    }

    /// Generic over the code lane width: [`PREDICT_BLOCK`]-row
    /// sub-blocks, tree-major sweep, leaf-index bit packing via
    /// `code > cut_rank` integer compares down the column-major code
    /// arrays.
    fn predict_block_t<T: Copy + Ord>(
        &self,
        codes: &[T],
        conv: impl Fn(u32) -> T,
        start: usize,
        acc_all: &mut [f32],
    ) {
        let leaves_w = 1usize << self.depth;
        let mut leaf_idx = [0usize; PREDICT_BLOCK];
        let mut off = start;
        for acc in acc_all.chunks_mut(PREDICT_BLOCK) {
            let m = acc.len();
            for t in 0..self.n_trees {
                let base = t * self.depth;
                leaf_idx[..m].fill(0);
                for d in 0..self.depth {
                    let col = self.node_col[base + d] as usize * self.n_rows;
                    let cut = conv(self.node_cut[base + d]);
                    let bit = 1usize << d;
                    let col_codes = &codes[col + off..col + off + m];
                    for (li, &c) in leaf_idx[..m].iter_mut().zip(col_codes) {
                        if c > cut {
                            *li |= bit;
                        }
                    }
                }
                let leaves = &self.leaves[t * leaves_w..(t + 1) * leaves_w];
                for (a, &li) in acc.iter_mut().zip(leaf_idx[..m].iter()) {
                    *a += leaves[li];
                }
            }
            off += m;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn random_ensemble(rng: &mut Pcg32, trees: usize, depth: usize, nf: usize) -> Ensemble {
        let leaves_w = 1 << depth;
        Ensemble {
            n_features: nf,
            depth,
            feat: (0..trees * depth)
                .map(|_| rng.gen_range(nf as u64) as u32)
                .collect(),
            thr: (0..trees * depth).map(|_| rng.f32()).collect(),
            leaves: (0..trees * leaves_w)
                .map(|_| rng.normal() as f32)
                .collect(),
            bias: 0.7,
        }
    }

    #[test]
    fn constant_predicts_bias() {
        let e = Ensemble::constant(4, 2.5);
        assert_eq!(e.predict(&[0.0; 8]), 2.5);
        assert_eq!(e.n_trees(), 0);
    }

    #[test]
    fn leaf_index_bit_packing() {
        // one tree, depth 2: level 0 on f0@0.5, level 1 on f1@0.5
        let e = Ensemble {
            n_features: 2,
            depth: 2,
            feat: vec![0, 1],
            thr: vec![0.5, 0.5],
            leaves: vec![10.0, 11.0, 12.0, 13.0],
            bias: 0.0,
        };
        assert_eq!(e.predict(&[0.0, 0.0]), 10.0); // 00
        assert_eq!(e.predict(&[1.0, 0.0]), 11.0); // 01 (bit 0 = level 0)
        assert_eq!(e.predict(&[0.0, 1.0]), 12.0); // 10
        assert_eq!(e.predict(&[1.0, 1.0]), 13.0); // 11
    }

    #[test]
    fn flatten_preserves_predictions() {
        let mut rng = Pcg32::new(42, 0);
        for (trees, depth) in [(0usize, 3usize), (1, 1), (8, 3), (48, 4), (64, 6)] {
            let e = if trees == 0 {
                Ensemble::constant(5, 1.25)
            } else {
                random_ensemble(&mut rng, trees, depth, 5)
            };
            let flat = e.flatten();
            for _ in 0..50 {
                let x: Vec<f32> = (0..F_MAX).map(|_| rng.f32()).collect();
                let want = e.predict(&x);
                let got = flat.predict(&x);
                assert!(
                    (want - got).abs() < 1e-4,
                    "trees={trees} depth={depth}: {want} vs {got}"
                );
            }
        }
    }

    #[test]
    fn predict_batch_matches_rowwise_across_block_boundaries() {
        let mut rng = Pcg32::new(77, 0);
        let e = random_ensemble(&mut rng, 48, 4, 6);
        let flat = e.flatten();
        for n in [0usize, 1, PREDICT_BLOCK - 1, PREDICT_BLOCK, PREDICT_BLOCK + 1, 200] {
            let xs: Vec<[f32; F_MAX]> = (0..n)
                .map(|_| {
                    let mut x = [0f32; F_MAX];
                    for v in x.iter_mut() {
                        *v = rng.f32();
                    }
                    x
                })
                .collect();
            let batch = e.predict_batch(&xs);
            let flat_batch = flat.predict_batch(&xs);
            assert_eq!(batch.len(), n);
            assert_eq!(flat_batch.len(), n);
            for (i, x) in xs.iter().enumerate() {
                assert!(
                    batch[i] == e.predict(x),
                    "n={n} row {i}: batch {} vs rowwise {}",
                    batch[i],
                    e.predict(x)
                );
                assert!(
                    flat_batch[i] == flat.predict(x),
                    "n={n} row {i}: flat batch {} vs rowwise {}",
                    flat_batch[i],
                    flat.predict(x)
                );
            }
        }
    }

    #[test]
    fn predict_batch_constant_and_zero_ensembles() {
        let e = Ensemble::constant(3, 2.5);
        let xs = vec![[0.1f32; F_MAX]; 130];
        assert!(e.predict_batch(&xs).iter().all(|&v| v == 2.5));
        // all-padding flat ensemble: every active-tree count is 0
        let z = FlatEnsemble::zero();
        assert!(z.predict_batch(&xs).iter().all(|&v| v == 0.0));
        // constant flatten folds the bias into tree 0
        let zf = e.flatten();
        assert!(zf.predict_batch(&xs).iter().all(|&v| v == 2.5));
    }

    #[test]
    fn zero_flat_is_neutral() {
        let z = FlatEnsemble::zero();
        assert_eq!(z.predict(&[0.3; F_MAX]), 0.0);
        assert_eq!(z.predict(&[0.9; F_MAX]), 0.0);
    }

    #[test]
    #[should_panic(expected = "exceed artifact capacity")]
    fn flatten_rejects_oversize() {
        let mut rng = Pcg32::new(1, 0);
        let e = random_ensemble(&mut rng, TREES_MAX + 1, 2, 3);
        e.flatten();
    }

    /// Random rows plus adversarial ones: exact threshold hits (the
    /// bin-boundary contract), NaN features, and ±0.0.
    fn quantize_test_rows(rng: &mut Pcg32, e: &Ensemble, n: usize) -> Vec<[f32; F_MAX]> {
        let mut xs: Vec<[f32; F_MAX]> = (0..n)
            .map(|_| {
                let mut x = [0f32; F_MAX];
                for v in x.iter_mut() {
                    *v = rng.f32() * 2.0 - 0.5;
                }
                x
            })
            .collect();
        for (i, x) in xs.iter_mut().enumerate() {
            match i % 5 {
                // land some features exactly on a node threshold:
                // `x > thr` must stay false on both paths
                0 if !e.feat.is_empty() => {
                    let k = i % e.feat.len();
                    x[e.feat[k] as usize] = e.thr[k];
                }
                1 => x[i % F_MAX] = f32::NAN,
                2 => x[i % F_MAX] = -0.0,
                3 => x[i % F_MAX] = 0.0,
                _ => {}
            }
        }
        xs
    }

    #[test]
    fn quantized_matches_predict_batch_bitwise() {
        let mut rng = Pcg32::new(2024, 8);
        for (trees, depth) in [(1usize, 1usize), (8, 3), (48, 4), (64, 6)] {
            let e = random_ensemble(&mut rng, trees, depth, 6);
            let xs = quantize_test_rows(&mut rng, &e, 300);
            let q = QuantizedEnsemble::build(&e, &xs);
            let want = e.predict_batch(&xs);
            let got = q.predict_all();
            assert_eq!(got.len(), want.len());
            for i in 0..want.len() {
                assert!(
                    got[i].to_bits() == want[i].to_bits(),
                    "trees={trees} depth={depth} row {i}: quantized {} vs batch {}",
                    got[i],
                    want[i]
                );
            }
        }
    }

    #[test]
    fn quantized_range_matches_all_and_flat_stays_close() {
        let mut rng = Pcg32::new(7, 81);
        let e = random_ensemble(&mut rng, 32, 4, 5);
        let xs = quantize_test_rows(&mut rng, &e, 401);
        let q = QuantizedEnsemble::build(&e, &xs);
        let all = q.predict_all();
        // chunked range predictions re-assemble the full vector bitwise
        let mut buf = [0f32; 96];
        let mut start = 0;
        while start < xs.len() {
            let m = 96.min(xs.len() - start);
            q.predict_range_into(start, &mut buf[..m]);
            for i in 0..m {
                assert_eq!(buf[i].to_bits(), all[start + i].to_bits());
            }
            start += m;
        }
        // bin-boundary-consistent with the artifact-shaped evaluator
        let flat = e.flatten().predict_batch(&xs);
        for i in 0..xs.len() {
            if xs[i].iter().any(|v| v.is_nan()) {
                // NaN rows fall left on every path; still finite output
                assert!(all[i].is_finite() && flat[i].is_finite());
            }
            assert!(
                (all[i] - flat[i]).abs() < 1e-4,
                "row {i}: quantized {} vs flat {}",
                all[i],
                flat[i]
            );
        }
    }

    #[test]
    fn quantized_u16_lane_when_cuts_exceed_u8() {
        // every node tests feature 0 with a distinct threshold:
        // TREES_MAX*DEPTH_MAX = 384 cuts on one column forces u16 codes
        let depth = DEPTH_MAX;
        let trees = TREES_MAX;
        let leaves_w = 1 << depth;
        let mut rng = Pcg32::new(5, 5);
        let e = Ensemble {
            n_features: 2,
            depth,
            feat: vec![0; trees * depth],
            thr: (0..trees * depth).map(|i| i as f32 / 384.0).collect(),
            leaves: (0..trees * leaves_w).map(|_| rng.normal() as f32).collect(),
            bias: 0.25,
        };
        let xs = quantize_test_rows(&mut rng, &e, 200);
        let q = QuantizedEnsemble::build(&e, &xs);
        assert!(matches!(q.codes, CodeStore::Owned(Codes::U16(_))));
        let want = e.predict_batch(&xs);
        let got = q.predict_all();
        for i in 0..want.len() {
            assert_eq!(got[i].to_bits(), want[i].to_bits(), "row {i}");
        }
        // the same ensemble re-ranked against a pool grid stays bitwise
        let pool = Arc::new(PoolCodes::build(&xs));
        let r = QuantizedEnsemble::rerank(&e, &pool);
        let rr = r.predict_all();
        for i in 0..want.len() {
            assert_eq!(rr[i].to_bits(), want[i].to_bits(), "rerank row {i}");
        }
    }

    /// Re-ranked quantization ≡ full build ≡ dense batch, bitwise —
    /// over adversarial rows (exact-threshold hits, NaN features,
    /// ±0.0) and a NaN-threshold node, across many ensembles sharing
    /// ONE pool grid (the amortized refit shape).
    #[test]
    fn reranked_matches_full_build_bitwise() {
        let mut rng = Pcg32::new(909, 3);
        let probe = random_ensemble(&mut rng, 8, 3, 6);
        let xs = quantize_test_rows(&mut rng, &probe, 333);
        let pool = Arc::new(PoolCodes::build(&xs));
        for (trees, depth) in [(1usize, 1usize), (8, 3), (48, 4), (64, 6)] {
            let mut e = random_ensemble(&mut rng, trees, depth, 6);
            // exercise the NaN-threshold sentinel rank
            e.thr[0] = f32::NAN;
            // and thresholds that collide exactly with pool values
            if e.thr.len() > 1 {
                e.thr[1] = xs[7][e.feat[1] as usize];
            }
            let want = e.predict_batch(&xs);
            let full = QuantizedEnsemble::build(&e, &xs).predict_all();
            let rer = QuantizedEnsemble::rerank(&e, &pool).predict_all();
            for i in 0..want.len() {
                assert_eq!(
                    full[i].to_bits(),
                    want[i].to_bits(),
                    "trees={trees} depth={depth} full row {i}"
                );
                assert_eq!(
                    rer[i].to_bits(),
                    want[i].to_bits(),
                    "trees={trees} depth={depth} rerank row {i}"
                );
            }
        }
    }

    /// `x > thr ⟺ code > rank` for the pool grid, probed directly on
    /// boundary values: exact hits, just-below/above, NaN, ±0.0.
    #[test]
    fn pool_codes_rank_predicate_exact() {
        let vals = [0.5f32, -0.0, 1.0, 0.5, f32::NAN, 0.0, -2.0, 1.5];
        let xs: Vec<[f32; F_MAX]> = vals
            .iter()
            .map(|&v| {
                let mut x = [0f32; F_MAX];
                x[0] = v;
                x
            })
            .collect();
        let pool = PoolCodes::build(&xs);
        let codes: Vec<u32> = xs
            .iter()
            .map(|row| {
                let x = row[0];
                if x.is_nan() {
                    0
                } else {
                    (pool.uniques[0].partition_point(|&u| u < x) + 1) as u32
                }
            })
            .collect();
        for &thr in &[-2.0f32, -0.5, -0.0, 0.0, 0.25, 0.5, 1.0, 1.25, 1.5, 2.0, f32::NAN] {
            let rank = pool.rank_of(0, thr);
            for (i, &x) in vals.iter().enumerate() {
                assert_eq!(
                    x > thr,
                    codes[i] > rank,
                    "x={x} thr={thr}: code {} rank {rank}",
                    codes[i]
                );
            }
        }
    }

    /// Pools whose columns carry more than 65 535 distinct values
    /// force the u32 lane; predictions stay bitwise-equal.
    #[test]
    fn pool_codes_u32_lane_when_uniques_exceed_u16() {
        let n = u16::MAX as usize + 10;
        let xs: Vec<[f32; F_MAX]> = (0..n)
            .map(|i| {
                let mut x = [0f32; F_MAX];
                x[0] = i as f32; // distinct up to 2^24: all unique here
                x[1] = (i % 7) as f32;
                x
            })
            .collect();
        let pool = Arc::new(PoolCodes::build(&xs));
        assert!(matches!(pool.codes, Codes::U32(_)));
        let mut rng = Pcg32::new(31, 7);
        let e = random_ensemble(&mut rng, 8, 3, 2);
        let want = e.predict_batch(&xs);
        let got = QuantizedEnsemble::rerank(&e, &pool).predict_all();
        for i in (0..n).step_by(997) {
            assert_eq!(got[i].to_bits(), want[i].to_bits(), "row {i}");
        }
        assert_eq!(got.len(), want.len());
    }

    #[test]
    fn quantized_constant_ensemble() {
        let e = Ensemble::constant(3, 1.5);
        let xs = vec![[0.4f32; F_MAX]; 50];
        let q = QuantizedEnsemble::build(&e, &xs);
        assert!(q.predict_all().iter().all(|&v| v == 1.5));
        assert_eq!(q.n_rows(), 50);
        assert!(q.approx_bytes() < 64);
    }
}
