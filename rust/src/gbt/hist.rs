//! Feature quantization for histogram-binned GBT training.
//!
//! Each feature column is quantized into `u8` bin codes against its
//! sorted candidate-threshold list.  The code of a sample is the
//! number of thresholds strictly below its value, so for candidate cut
//! `k` the right child is exactly `{i : code(i) > k}` — bit-for-bit
//! the same partition the exact trainer derives from `x > thr`.
//! Split search then needs one O(n·F) histogram pass per tree level
//! plus an O(leaves·F·bins) scan, instead of rescanning all n samples
//! per candidate.
//!
//! A dataset quantizes once per *session*, not once per training call:
//! [`BinnedDataset::push_rows`] appends fresh measurements by merging
//! their values into the per-feature sorted-unique arrays, re-deriving
//! the candidate thresholds from the merged uniques, and re-coding a
//! column **only when its thresholds actually changed** — the exact
//! drift criterion, so the incremental dataset is always bitwise equal
//! to a from-scratch [`BinnedDataset::build`] of the concatenated rows
//! (pinned by property tests below).

use crate::config::F_MAX;
use crate::util::parallel;

/// Hard cap on candidate thresholds per feature: codes live in `u8`
/// and range over `0..=n_thresholds`, so at most 255 thresholds
/// (256 bins) are representable.
pub const MAX_THRESHOLDS: usize = 255;

/// Quantization / histogram passes dispatch to the worker pool only
/// when the pass touches at least this many (row, feature) cells —
/// below it (the paper's 25-100-sample training sets) the fork-join
/// hand-off costs more than it saves and the code runs inline.
pub(crate) const PAR_MIN_CELLS: usize = 4096;

/// Candidate split thresholds per feature: midpoints between adjacent
/// quantiles of the observed values, sorted ascending and deduplicated.
/// Shared by the histogram and exact engines so both search the same
/// candidate set.
pub fn candidate_thresholds(xs: &[[f32; F_MAX]], f: usize, n_bins: usize) -> Vec<f32> {
    let mut vals: Vec<f32> = xs.iter().map(|x| x[f]).collect();
    vals.sort_by(|a, b| a.partial_cmp(b).expect("NaN feature"));
    vals.dedup();
    thresholds_from_uniques(&vals, n_bins)
}

/// The threshold rule over an already sorted-and-deduplicated value
/// array — the shared tail of [`candidate_thresholds`] and the
/// incremental [`BinnedDataset::push_rows`] path (which maintains the
/// unique arrays across appends instead of re-sorting every call).
pub(crate) fn thresholds_from_uniques(vals: &[f32], n_bins: usize) -> Vec<f32> {
    if vals.len() < 2 {
        return Vec::new();
    }
    let n_cand = n_bins.min(MAX_THRESHOLDS).min(vals.len() - 1);
    let mut out = Vec::with_capacity(n_cand);
    for i in 0..n_cand {
        // evenly spaced quantile boundaries over unique values
        let pos = (i + 1) * (vals.len() - 1) / (n_cand + 1);
        let pos = pos.min(vals.len() - 2);
        let mid = 0.5 * (vals[pos] + vals[pos + 1]);
        out.push(mid);
    }
    out.dedup();
    out
}

/// A dataset quantized once per session, extended in place as fresh
/// measurements arrive ([`Self::push_rows`]).
pub struct BinnedDataset {
    pub n_rows: usize,
    pub n_features: usize,
    /// The bin budget the thresholds were derived under (push_rows
    /// re-derives with the same budget).
    bin_budget: usize,
    /// Sorted candidate thresholds per feature; cut `k` sends a sample
    /// right iff `x > thresholds[f][k]`.
    pub thresholds: Vec<Vec<f32>>,
    /// Per-feature sorted distinct values the thresholds derive from
    /// (stable first-occurrence representatives among numeric ties,
    /// matching stable-sort + dedup of the raw column).
    uniques: Vec<Vec<f32>>,
    /// Per-feature raw value columns, kept for full column re-codes
    /// when an append shifts that feature's threshold grid.
    raw: Vec<Vec<f32>>,
    /// Per-feature bin codes, one per row:
    /// `codes[f][i] = #{k : xs[i][f] > thresholds[f][k]}`.
    codes: Vec<Vec<u8>>,
    /// Per-feature offset into a per-leaf histogram row; feature `f`
    /// owns slots `offset[f] .. offset[f] + n_bins(f)`.
    offsets: Vec<usize>,
    /// Σ_f n_bins(f) — the stride of one leaf's histogram row.
    pub total_bins: usize,
}

/// Merge a batch of (stable-sorted, deduplicated) new values into an
/// existing unique array, keeping the *existing* representative on
/// numeric ties — exactly what stable-sort + dedup of the concatenated
/// column produces, since earlier rows sort ahead of later equals.
fn merge_uniques(existing: &[f32], new_vals: &[f32]) -> Vec<f32> {
    let mut out = Vec::with_capacity(existing.len() + new_vals.len());
    let (mut i, mut j) = (0, 0);
    while i < existing.len() && j < new_vals.len() {
        if existing[i] <= new_vals[j] {
            if existing[i] == new_vals[j] {
                j += 1; // numeric tie: the existing representative wins
            }
            out.push(existing[i]);
            i += 1;
        } else {
            out.push(new_vals[j]);
            j += 1;
        }
    }
    out.extend_from_slice(&existing[i..]);
    out.extend_from_slice(&new_vals[j..]);
    out
}

impl BinnedDataset {
    /// Quantize the first `n_features` columns of `xs` against at most
    /// `n_bins` candidate thresholds per feature.
    ///
    /// Features quantize independently, so the pass forks one task per
    /// feature across the worker pool (each task sorts its own unique
    /// array and writes its own code column — single writer per slot,
    /// bit-identical for any worker count).
    pub fn build(xs: &[[f32; F_MAX]], n_features: usize, n_bins: usize) -> BinnedDataset {
        let n = xs.len();
        let width = parallel::width_for(n * n_features, PAR_MIN_CELLS);
        let cols = parallel::map_indexed(width, n_features, |f| {
            let raw: Vec<f32> = xs.iter().map(|x| x[f]).collect();
            let mut uniq = raw.clone();
            uniq.sort_by(|a, b| a.partial_cmp(b).expect("NaN feature"));
            uniq.dedup();
            let thr = thresholds_from_uniques(&uniq, n_bins);
            let codes: Vec<u8> = raw
                .iter()
                .map(|&v| thr.partition_point(|&t| v > t) as u8)
                .collect();
            (raw, uniq, thr, codes)
        });
        let mut b = BinnedDataset {
            n_rows: n,
            n_features,
            bin_budget: n_bins,
            thresholds: Vec::with_capacity(n_features),
            uniques: Vec::with_capacity(n_features),
            raw: Vec::with_capacity(n_features),
            codes: Vec::with_capacity(n_features),
            offsets: Vec::new(),
            total_bins: 0,
        };
        for (raw, uniq, thr, codes) in cols {
            b.raw.push(raw);
            b.uniques.push(uniq);
            b.thresholds.push(thr);
            b.codes.push(codes);
        }
        b.rebuild_offsets();
        b
    }

    /// Append rows, keeping the dataset **bitwise equal** to a
    /// from-scratch [`Self::build`] of the concatenated rows:
    ///
    /// 1. merge the new values into each feature's sorted-unique array
    ///    (O(uniques + new) per column, no full re-sort);
    /// 2. re-derive that column's thresholds from the merged uniques
    ///    (the same rule `build` applies);
    /// 3. if the thresholds are bit-identical to before, bin only the
    ///    new rows; otherwise re-code the stored raw column once.
    ///
    /// Step 3 is the exact drift criterion — a column pays its O(n)
    /// re-code only when its grid actually moved, and the result never
    /// diverges from the reference.  Appends are session-sized (a few
    /// rows against a few hundred), so the pass runs inline.
    pub fn push_rows(&mut self, xs_new: &[[f32; F_MAX]]) {
        if xs_new.is_empty() {
            return;
        }
        for f in 0..self.n_features {
            let mut fresh: Vec<f32> = xs_new.iter().map(|x| x[f]).collect();
            fresh.sort_by(|a, b| a.partial_cmp(b).expect("NaN feature"));
            fresh.dedup();
            let merged = merge_uniques(&self.uniques[f], &fresh);
            let thr = thresholds_from_uniques(&merged, self.bin_budget);
            let unchanged = thr.len() == self.thresholds[f].len()
                && thr
                    .iter()
                    .zip(&self.thresholds[f])
                    .all(|(a, b)| a.to_bits() == b.to_bits());
            self.raw[f].extend(xs_new.iter().map(|x| x[f]));
            if unchanged {
                self.codes[f].extend(
                    xs_new
                        .iter()
                        .map(|x| thr.partition_point(|&t| x[f] > t) as u8),
                );
            } else {
                let raw = &self.raw[f];
                self.codes[f].clear();
                self.codes[f]
                    .extend(raw.iter().map(|&v| thr.partition_point(|&t| v > t) as u8));
            }
            self.uniques[f] = merged;
            self.thresholds[f] = thr;
        }
        self.n_rows += xs_new.len();
        self.rebuild_offsets();
    }

    fn rebuild_offsets(&mut self) {
        self.offsets.clear();
        self.total_bins = 0;
        for thr in &self.thresholds {
            self.offsets.push(self.total_bins);
            self.total_bins += thr.len() + 1;
        }
    }

    /// Bin codes of feature `f`, one per row.
    #[inline]
    pub fn feature_codes(&self, f: usize) -> &[u8] {
        &self.codes[f]
    }

    /// Number of histogram bins of feature `f` (thresholds + 1).
    #[inline]
    pub fn n_bins(&self, f: usize) -> usize {
        self.thresholds[f].len() + 1
    }

    /// Offset of feature `f`'s bins inside one leaf's histogram row.
    #[inline]
    pub fn offset(&self, f: usize) -> usize {
        self.offsets[f]
    }
}

/// Per-level gradient/count histograms: for every (leaf, feature, bin)
/// the summed gradient and sample count.  Counts double as hessians —
/// the squared-error objective has `h_i = 1` — so child hessian sums
/// are exact integers, identical to the exact engine's.
pub struct LevelHistogram {
    /// `[n_leaves * total_bins]` summed gradients.
    pub grad: Vec<f64>,
    /// `[n_leaves * total_bins]` sample counts.
    pub count: Vec<u32>,
}

impl LevelHistogram {
    pub fn new(n_leaves: usize, total_bins: usize) -> LevelHistogram {
        LevelHistogram {
            grad: vec![0.0; n_leaves * total_bins],
            count: vec![0; n_leaves * total_bins],
        }
    }

    /// Zero and re-accumulate all features for leaves `0..n_leaves` in
    /// one pass over the samples per feature: O(n · F) total,
    /// independent of the number of candidate thresholds.
    ///
    /// The pass **partitions features across workers** (`width`-wide
    /// fork-join on the process pool): feature `f` owns the histogram
    /// columns `{leaf * stride + offset(f) + bin}`, so every
    /// (leaf, feature, bin) cell has exactly one writer, no merge step
    /// exists, and the result is bit-identical for every worker count.
    pub fn fill(
        &mut self,
        binned: &BinnedDataset,
        leaf_of: &[usize],
        grad: &[f64],
        n_leaves: usize,
        width: usize,
    ) {
        self.fill_scan(binned, leaf_of, grad, n_leaves, width, |_, _| ());
    }

    /// [`fill`](Self::fill) fused with a per-feature post-pass: after
    /// feature `f`'s columns are filled, `scan(f, view)` runs *inside
    /// the same task* (split search, in the trainer), and the results
    /// are collected in feature order.  One fork-join per tree level
    /// instead of two.
    pub fn fill_scan<R: Send>(
        &mut self,
        binned: &BinnedDataset,
        leaf_of: &[usize],
        grad: &[f64],
        n_leaves: usize,
        width: usize,
        scan: impl for<'v> Fn(usize, FeatureHist<'v>) -> R + Sync,
    ) -> Vec<R> {
        // Real asserts, not debug: the fill writes through raw pointers
        // (one writer per cell), so caller mistakes must stay a panic —
        // as they were under the old bounds-checked indexing — never an
        // out-of-bounds write in release builds.
        assert_eq!(leaf_of.len(), binned.n_rows, "leaf_of length mismatch");
        assert!(
            n_leaves * binned.total_bins <= self.grad.len()
                && n_leaves * binned.total_bins <= self.count.len(),
            "histogram sized for fewer than {n_leaves} leaves"
        );
        assert!(
            leaf_of.iter().all(|&l| l < n_leaves),
            "leaf index out of range"
        );
        let stride = binned.total_bins;
        let gp = parallel::SendPtr::new(self.grad.as_mut_ptr());
        let cp = parallel::SendPtr::new(self.count.as_mut_ptr());
        parallel::map_indexed(width, binned.n_features, move |f| {
            let off = binned.offset(f);
            let nb = binned.n_bins(f);
            let codes = binned.feature_codes(f);
            // SAFETY: feature `f` owns slots {l*stride + off + b} for
            // b < nb; the per-feature slot ranges are pairwise disjoint,
            // so this task is the only writer of every cell it touches.
            unsafe {
                let g = gp.get();
                let c = cp.get();
                for l in 0..n_leaves {
                    let base = l * stride + off;
                    for b in 0..nb {
                        *g.add(base + b) = 0.0;
                        *c.add(base + b) = 0;
                    }
                }
                for (i, &leaf) in leaf_of.iter().enumerate() {
                    let slot = leaf * stride + off + codes[i] as usize;
                    *g.add(slot) += grad[i];
                    *c.add(slot) += 1;
                }
            }
            scan(
                f,
                FeatureHist {
                    grad: gp,
                    count: cp,
                    stride,
                    off,
                    n_leaves,
                    n_bins: nb,
                    _hist: std::marker::PhantomData,
                },
            )
        })
    }

    /// Gradient sum of (leaf `l`, feature-offset `off`, bin `b`).
    #[inline]
    pub fn grad_at(&self, stride: usize, l: usize, off: usize, b: usize) -> f64 {
        self.grad[l * stride + off + b]
    }

    /// Sample count of (leaf `l`, feature-offset `off`, bin `b`).
    #[inline]
    pub fn count_at(&self, stride: usize, l: usize, off: usize, b: usize) -> u32 {
        self.count[l * stride + off + b]
    }
}

/// Read-only view of one feature's freshly filled histogram columns,
/// handed to the [`LevelHistogram::fill_scan`] callback.  Only valid
/// for the feature whose task created it: other features' columns may
/// still be written concurrently by their own tasks.  The lifetime
/// ties the view to the histogram borrow (and, via the callback's
/// higher-ranked bound, keeps it from escaping its task), so safe
/// code cannot read through it after the histogram is gone.
pub struct FeatureHist<'a> {
    grad: parallel::SendPtr<f64>,
    count: parallel::SendPtr<u32>,
    stride: usize,
    off: usize,
    n_leaves: usize,
    n_bins: usize,
    _hist: std::marker::PhantomData<&'a LevelHistogram>,
}

impl FeatureHist<'_> {
    /// Summed gradient of (leaf `l`, bin `b`) of this view's feature.
    #[inline]
    pub fn grad(&self, l: usize, b: usize) -> f64 {
        assert!(l < self.n_leaves && b < self.n_bins, "FeatureHist read out of range");
        // SAFETY: (l, b) is in range (asserted), so the slot is inside
        // this feature's range, which the creating task owns
        // exclusively (see `fill_scan`).
        unsafe { *self.grad.get().add(l * self.stride + self.off + b) }
    }

    /// Sample count of (leaf `l`, bin `b`) of this view's feature.
    #[inline]
    pub fn count(&self, l: usize, b: usize) -> u32 {
        assert!(l < self.n_leaves && b < self.n_bins, "FeatureHist read out of range");
        // SAFETY: as for `grad`.
        unsafe { *self.count.get().add(l * self.stride + self.off + b) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn rows(rng: &mut Pcg32, n: usize) -> Vec<[f32; F_MAX]> {
        (0..n)
            .map(|_| {
                let mut x = [0f32; F_MAX];
                for v in x.iter_mut() {
                    *v = rng.f32();
                }
                x
            })
            .collect()
    }

    #[test]
    fn codes_match_threshold_semantics() {
        let mut rng = Pcg32::new(11, 0);
        let xs = rows(&mut rng, 300);
        let b = BinnedDataset::build(&xs, 5, 32);
        for f in 0..5 {
            let thr = &b.thresholds[f];
            let codes = b.feature_codes(f);
            for (i, x) in xs.iter().enumerate() {
                let want = thr.iter().filter(|&&t| x[f] > t).count();
                assert_eq!(codes[i] as usize, want, "f={f} i={i}");
                // right-child membership of every cut agrees with x > t
                for (k, &t) in thr.iter().enumerate() {
                    assert_eq!(codes[i] as usize > k, x[f] > t);
                }
            }
        }
    }

    #[test]
    fn thresholds_sorted_and_bounded() {
        let mut rng = Pcg32::new(12, 0);
        let xs = rows(&mut rng, 500);
        let b = BinnedDataset::build(&xs, 4, 1000);
        for f in 0..4 {
            let thr = &b.thresholds[f];
            assert!(thr.len() <= MAX_THRESHOLDS);
            assert!(thr.windows(2).all(|w| w[0] < w[1]), "unsorted thresholds");
            assert_eq!(b.n_bins(f), thr.len() + 1);
        }
        assert_eq!(b.total_bins, (0..4).map(|f| b.n_bins(f)).sum::<usize>());
    }

    #[test]
    fn constant_feature_has_no_thresholds() {
        let xs = vec![[0.25f32; F_MAX]; 50];
        let b = BinnedDataset::build(&xs, 3, 16);
        for f in 0..3 {
            assert!(b.thresholds[f].is_empty());
            assert!(b.feature_codes(f).iter().all(|&c| c == 0));
        }
    }

    #[test]
    fn histogram_totals_match_leaf_totals() {
        let mut rng = Pcg32::new(13, 0);
        let xs = rows(&mut rng, 200);
        let b = BinnedDataset::build(&xs, 3, 8);
        let grad: Vec<f64> = (0..200).map(|_| rng.normal()).collect();
        let leaf_of: Vec<usize> = (0..200).map(|_| rng.gen_range(4) as usize).collect();
        let mut h = LevelHistogram::new(4, b.total_bins);
        h.fill(&b, &leaf_of, &grad, 4, 1);
        for l in 0..4 {
            let want_cnt = leaf_of.iter().filter(|&&x| x == l).count() as u32;
            let want_g: f64 = (0..200).filter(|&i| leaf_of[i] == l).map(|i| grad[i]).sum();
            for f in 0..3 {
                let off = b.offset(f);
                let cnt: u32 = (0..b.n_bins(f))
                    .map(|bi| h.count_at(b.total_bins, l, off, bi))
                    .sum();
                let g: f64 = (0..b.n_bins(f))
                    .map(|bi| h.grad_at(b.total_bins, l, off, bi))
                    .sum();
                assert_eq!(cnt, want_cnt, "leaf {l} feature {f}");
                assert!((g - want_g).abs() < 1e-9, "leaf {l} feature {f}");
            }
        }
    }

    /// Bitwise structural equality of two datasets: thresholds, codes,
    /// offsets, bin layout.
    fn assert_binned_identical(a: &BinnedDataset, b: &BinnedDataset, label: &str) {
        assert_eq!(a.n_rows, b.n_rows, "{label}: n_rows");
        assert_eq!(a.total_bins, b.total_bins, "{label}: total_bins");
        for f in 0..a.n_features {
            assert_eq!(
                a.thresholds[f].len(),
                b.thresholds[f].len(),
                "{label}: f={f} threshold count"
            );
            assert!(
                a.thresholds[f]
                    .iter()
                    .zip(&b.thresholds[f])
                    .all(|(x, y)| x.to_bits() == y.to_bits()),
                "{label}: f={f} thresholds diverge"
            );
            assert_eq!(a.feature_codes(f), b.feature_codes(f), "{label}: f={f} codes");
            assert_eq!(a.offset(f), b.offset(f), "{label}: f={f} offset");
            assert!(
                a.uniques[f]
                    .iter()
                    .zip(&b.uniques[f])
                    .all(|(x, y)| x.to_bits() == y.to_bits())
                    && a.uniques[f].len() == b.uniques[f].len(),
                "{label}: f={f} uniques diverge"
            );
        }
    }

    /// Property pin: any append schedule of `push_rows` calls is
    /// bitwise equal to one from-scratch `build` of the concatenation —
    /// across random chunk sizes, duplicate values (quantized features
    /// collide constantly), and several bin budgets.
    #[test]
    fn push_rows_matches_from_scratch_rebuild_bitwise() {
        let mut rng = Pcg32::new(0x9135, 0);
        for trial in 0..12u64 {
            let n_bins = [4usize, 16, 32, 255][trial as usize % 4];
            let nf = 3 + (trial as usize % 4);
            // coarse value lattice → plenty of cross-batch duplicates
            let row = |rng: &mut Pcg32| {
                let mut x = [0f32; F_MAX];
                for v in x.iter_mut().take(nf) {
                    *v = (rng.gen_range(23) as f32) / 7.0 - 1.0;
                }
                x
            };
            let n0 = 1 + rng.gen_range(40) as usize;
            let mut all: Vec<[f32; F_MAX]> = (0..n0).map(|_| row(&mut rng)).collect();
            let mut inc = BinnedDataset::build(&all, nf, n_bins);
            for _ in 0..5 {
                let k = rng.gen_range(25) as usize; // may be 0: no-op append
                let fresh: Vec<[f32; F_MAX]> = (0..k).map(|_| row(&mut rng)).collect();
                inc.push_rows(&fresh);
                all.extend_from_slice(&fresh);
                let scratch = BinnedDataset::build(&all, nf, n_bins);
                assert_binned_identical(&inc, &scratch, &format!("trial {trial} n={}", all.len()));
            }
        }
    }

    /// Appends that leave every grid unchanged (pure duplicates) take
    /// the cheap append path; appends that move a grid re-code — either
    /// way the reference equality holds, including ±0.0 ties.
    #[test]
    fn push_rows_duplicate_and_signed_zero_appends() {
        let base: Vec<[f32; F_MAX]> = [0.0f32, 1.0, 2.0, 3.0, 1.0, 2.0]
            .iter()
            .map(|&v| {
                let mut x = [0f32; F_MAX];
                x[0] = v;
                x[1] = -v;
                x
            })
            .collect();
        let mut inc = BinnedDataset::build(&base, 2, 8);
        let mut all = base.clone();
        // batch 1: pure duplicates (grids must not move)
        let thr_before: Vec<u32> = inc.thresholds[0].iter().map(|t| t.to_bits()).collect();
        let dup: Vec<[f32; F_MAX]> = all[1..3].to_vec();
        inc.push_rows(&dup);
        all.extend_from_slice(&dup);
        let thr_after: Vec<u32> = inc.thresholds[0].iter().map(|t| t.to_bits()).collect();
        assert_eq!(thr_before, thr_after, "duplicate append moved the grid");
        assert_binned_identical(&inc, &BinnedDataset::build(&all, 2, 8), "dup batch");
        // batch 2: -0.0 against an existing +0.0 (numeric tie: the
        // existing representative must win, as stable sort+dedup does)
        let mut z = [0f32; F_MAX];
        z[0] = -0.0;
        z[1] = 7.0;
        inc.push_rows(&[z]);
        all.push(z);
        assert_binned_identical(&inc, &BinnedDataset::build(&all, 2, 8), "signed zero");
        // batch 3: new extremes force a re-code of both columns
        let mut e = [0f32; F_MAX];
        e[0] = -5.0;
        e[1] = 11.0;
        inc.push_rows(&[e]);
        all.push(e);
        assert_binned_identical(&inc, &BinnedDataset::build(&all, 2, 8), "grid shift");
    }

    /// The per-feature parallel fill must be bit-identical to the
    /// sequential pass for any worker count (single writer per cell).
    #[test]
    fn fill_is_thread_count_invariant() {
        let mut rng = Pcg32::new(14, 0);
        let xs = rows(&mut rng, 400);
        let b = BinnedDataset::build(&xs, 6, 16);
        let grad: Vec<f64> = (0..400).map(|_| rng.normal()).collect();
        let leaf_of: Vec<usize> = (0..400).map(|_| rng.gen_range(8) as usize).collect();
        let mut reference = LevelHistogram::new(8, b.total_bins);
        reference.fill(&b, &leaf_of, &grad, 8, 1);
        for width in [2usize, 5, 8] {
            let mut h = LevelHistogram::new(8, b.total_bins);
            h.fill(&b, &leaf_of, &grad, 8, width);
            assert_eq!(h.count, reference.count, "width {width}");
            assert!(
                h.grad.iter().zip(&reference.grad).all(|(a, r)| a == r),
                "gradients diverged at width {width}"
            );
        }
    }
}
