//! Gradient-boosted *oblivious* decision trees — the surrogate-model
//! family (the paper uses xgboost regressors; see DESIGN.md §2 for the
//! substitution).
//!
//! Oblivious trees apply one shared (feature, threshold) split per
//! level, so a trained ensemble flattens into three dense tensors
//! (`features[T,D]`, `thresholds[T,D]`, `leaves[T,2^D]`) that the AOT
//! Pallas kernel evaluates without re-compilation.  [`train`] fits an
//! ensemble with second-order histogram split search; [`Ensemble`]
//! carries the flattened format plus an exact native predictor used for
//! cross-checking the PJRT path and for multi-threaded campaigns.

pub mod ensemble;
pub mod train;

pub use ensemble::{Ensemble, FlatEnsemble, DEPTH_MAX, LEAVES_MAX, NEG_PRED, TREES_MAX};
pub use train::{train, train_log, GbtParams};
