//! Gradient-boosted *oblivious* decision trees — the surrogate-model
//! family (the paper uses xgboost regressors; see DESIGN.md §2 for the
//! substitution).
//!
//! Oblivious trees apply one shared (feature, threshold) split per
//! level, so a trained ensemble flattens into three dense tensors
//! (`features[T,D]`, `thresholds[T,D]`, `leaves[T,2^D]`) that the AOT
//! Pallas kernel evaluates without re-compilation.
//!
//! ## Binned training layout
//!
//! [`train`] fits an ensemble with second-order **histogram-binned**
//! split search: [`hist::BinnedDataset`] quantizes every feature column
//! once into `u8` bin codes against its sorted candidate thresholds
//! (a sample's code = number of thresholds strictly below its value,
//! so candidate cut `k`'s right child is exactly `{code > k}` — the
//! same partition the `x > thr` rule induces).  Each tree level then
//! accumulates per-(leaf, feature, bin) gradient sums and sample
//! counts in a single O(n·F) pass ([`hist::LevelHistogram`]) and
//! scores *all* candidate cuts from bin suffix sums in
//! O(leaves·F·bins), replacing the pre-histogram engine's full-data
//! rescan per candidate (O(F·bins·n) per level).  Counts double as
//! hessian sums (squared error ⇒ `h_i = 1`), so child-weight
//! constraints and leaf solves are exact integers, identical across
//! engines.  The brute-force engine survives as [`train_exact`], the
//! differential-testing oracle and benchmark baseline.
//!
//! ## Batched scoring layout
//!
//! [`Ensemble`] carries the compact trained model plus the exact
//! native predictor used for cross-checking the PJRT path and for
//! multi-threaded campaigns.  `Ensemble::predict_batch` and
//! `FlatEnsemble::predict_batch` are the cache-friendly hot path:
//! rows are processed in blocks of [`ensemble::PREDICT_BLOCK`]
//! (structure-of-arrays leaf-index registers, tree-major sweep) so
//! each tree's level tensors are loaded once per block instead of
//! once per row, while per-row results stay equal to the
//! row-at-a-time predictors.  Pool-sized batches shard fixed row
//! chunks across the process worker pool (bit-identical for any
//! worker count); batches under [`ensemble::PREDICT_SMALL`] skip the
//! block/dispatch setup entirely.  Training parallelizes the same
//! way: one task per feature per tree level, single writer per
//! histogram cell, ordered split reduction.
//!
//! At pool scale (≥ [`ensemble::QUANTIZE_MIN_ROWS`] rows) scoring
//! additionally routes through [`ensemble::QuantizedEnsemble`]: the
//! training-side binning idea applied to inference — pool features
//! coded into flat `u8`/`u16`/`u32` columns, thresholds as cut ranks,
//! traversal as integer compares — with predictions bitwise equal to
//! `Ensemble::predict_batch`.
//!
//! ## Amortized refits
//!
//! Both sides of a tuning iteration amortize across the session:
//!
//! * **Selection** — [`ensemble::PoolCodes`] codes each pool feature
//!   column *once per pool* by rank in its sorted-unique value array
//!   (model-independent); each refit's `QuantizedEnsemble` is then
//!   produced by [`ensemble::QuantizedEnsemble::rerank`], which only
//!   re-ranks the new ensemble's thresholds into that fixed grid —
//!   O(trees·depth·log uniques) instead of the O(pool·F) recode of
//!   [`ensemble::QuantizedEnsemble::build`].  Exact because `x > thr`
//!   is decided entirely by `rank(x)` vs `rank_of(thr)`.
//! * **Training** — [`hist::BinnedDataset::push_rows`] extends a
//!   session's binned dataset with the rows added since the last
//!   refit (bitwise equal to rebuilding from the concatenation), and
//!   [`train::IncrementalTrainer`] wraps it with a fingerprint gate
//!   that returns the cached ensemble outright when the exact
//!   training inputs are unchanged.  [`train_log_binned`] trains
//!   straight from a retained dataset.
//!
//! [`ensemble::amortization_counters`] exposes process-wide counters
//! (pool code builds, re-ranks, full quantized builds, refit skips)
//! so tests and the CLI can assert the amortization actually holds.

pub mod ensemble;
pub mod hist;
pub mod train;

pub use ensemble::{
    amortization_counters, AmortCounters, Ensemble, FlatEnsemble, PoolCodes, QuantizedEnsemble,
    DEPTH_MAX, LEAVES_MAX, NEG_PRED, PREDICT_BLOCK, PREDICT_SMALL, QUANTIZE_MIN_ROWS, TREES_MAX,
};
pub use hist::BinnedDataset;
pub use train::{
    train, train_exact, train_log, train_log_binned, train_log_exact, GbtParams,
    IncrementalTrainer,
};
