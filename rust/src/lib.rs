//! # ceal — in-situ workflow auto-tuning via combined component models
//!
//! Reproduction of *"In-situ Workflow Auto-tuning via Combining
//! Performance Models of Component Applications"* (CEAL, cs.DC 2020).
//!
//! The crate is the Layer-3 Rust coordinator of a three-layer stack:
//!
//! * **L3 (this crate)** — configuration spaces, the in-situ workflow
//!   simulator substrate, gradient-boosted-tree training, the CEAL
//!   auto-tuning algorithm and its baselines (RS / AL / GEIST / ALpH),
//!   metrics, and the experiment harness for every paper table/figure.
//! * **L2 (python/compile/model.py)** — JAX scoring graphs (ensemble
//!   inference + Eqn 1/2 low-fidelity combination), AOT-lowered once to
//!   HLO text in `artifacts/`.
//! * **L1 (python/compile/kernels/)** — the Pallas oblivious-GBT
//!   inference kernel those graphs call.
//!
//! Python never runs on the tuning path: [`runtime`] loads the HLO
//! artifacts via PJRT and executes them with trained ensembles passed
//! as runtime tensors.

// Clippy runs as a tier-1 CI gate (`-D warnings`).  These idioms are
// deliberate across the simulator/GBT/tuner numeric code: index-driven
// loops mirror the paper's recurrences over several parallel arrays,
// and ceiling divisions / precise float literals / wide profile
// signatures keep hot-path arithmetic explicit.  Anything else is held
// to the gate.
#![allow(
    clippy::needless_range_loop,
    clippy::manual_div_ceil,
    clippy::too_many_arguments,
    clippy::excessive_precision
)]

pub mod config;
pub mod coordinator;
pub mod exper;
pub mod gbt;
pub mod metrics;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod surrogate;
pub mod tuner;
pub mod util;
