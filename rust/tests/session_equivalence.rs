//! Equivalence pinning for the ask/tell redesign: every algorithm,
//! driven stepwise through `drive(session, Collector)`, must produce a
//! `TunerOutput` bit-identical to the frozen monolithic reference
//! loops in `ceal::tuner::legacy` — measured set, searcher pick, cost
//! accounting and final-model predictions alike — across the paper
//! trio and the registry-added scenarios.  Also pins replay == record
//! for the trace evaluator and the session diagnostics sink.

use std::sync::Arc;

use ceal::config::WorkflowId;
use ceal::coordinator::historical_samples;
use ceal::sim::Objective;
use ceal::surrogate::Scorer;
use ceal::tuner::{
    drive, legacy, ActiveLearning, Alph, BudgetedCeal, BudgetedCealParams, Ceal, CealParams,
    Collector, DiagSink, Evaluator, Geist, Pool, Problem, RandomSampling, TraceHeader,
    TraceRecorder, TraceReplayer, Tuner, TunerOutput,
};
use ceal::util::rng::Pcg32;

/// The full bit-identity check: the measured trajectory, the searcher
/// pick, the accounting, and the final model's predictions over the
/// whole pool.
fn assert_outputs_identical(label: &str, a: &TunerOutput, b: &TunerOutput, pool: &Pool) {
    assert_eq!(a.measured, b.measured, "{label}: measured trajectories diverge");
    assert_eq!(a.best_idx, b.best_idx, "{label}: searcher picks diverge");
    assert_eq!(
        a.collection_cost.to_bits(),
        b.collection_cost.to_bits(),
        "{label}: collection cost diverges ({} vs {})",
        a.collection_cost,
        b.collection_cost
    );
    assert_eq!(a.workflow_runs, b.workflow_runs, "{label}: run counts diverge");
    // trained ensembles compare structurally (trees, thresholds, leaf
    // values) — stronger than prediction equality
    assert_eq!(a.model, b.model, "{label}: final models diverge");
    let scorer = Scorer::Native;
    let pa = scorer.score(&a.model, &pool.feats.workflow);
    let pb = scorer.score(&b.model, &pool.feats.workflow);
    for (i, (x, y)) in pa.iter().zip(&pb).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{label}: model predictions diverge at pool row {i}"
        );
    }
}

/// The five pinned cells: paper trio + the synthetic registry
/// scenarios, alternating objectives so both max- and sum-combined
/// low-fidelity models are exercised.
fn cells() -> Vec<(WorkflowId, Objective)> {
    vec![
        (WorkflowId::LV, Objective::CompTime),
        (WorkflowId::HS, Objective::ExecTime),
        (WorkflowId::GP, Objective::CompTime),
        (WorkflowId::CH5, Objective::ExecTime),
        (WorkflowId::DM4, Objective::ExecTime),
    ]
}

#[test]
fn every_algorithm_matches_legacy_on_every_workflow() {
    let scorer = Scorer::Native;
    let m = 20;
    for (k, (wf, obj)) in cells().into_iter().enumerate() {
        let prob = Problem::new(wf, obj);
        let pool = Pool::generate(&prob, 120, 0x5E55 + k as u64);
        let seed = 0xA11C + k as u64;
        let pair = |stream: u64| (Pcg32::new(seed, stream), Pcg32::new(seed, stream));

        // RS
        let (mut r1, mut r2) = pair(1);
        let old = legacy::run_rs(&prob, &pool, &scorer, m, &mut r1);
        let new = RandomSampling.run(&prob, &pool, &scorer, m, &mut r2);
        assert_outputs_identical(&format!("RS/{wf}"), &old, &new, &pool);

        // AL
        let al = ActiveLearning::default();
        let (mut r1, mut r2) = pair(2);
        let old = legacy::run_al(&al, &prob, &pool, &scorer, m, &mut r1);
        let new = al.run(&prob, &pool, &scorer, m, &mut r2);
        assert_outputs_identical(&format!("AL/{wf}"), &old, &new, &pool);

        // GEIST
        let geist = Geist::default();
        let (mut r1, mut r2) = pair(3);
        let old = legacy::run_geist(&geist, &prob, &pool, &scorer, m, &mut r1);
        let new = geist.run(&prob, &pool, &scorer, m, &mut r2);
        assert_outputs_identical(&format!("GEIST/{wf}"), &old, &new, &pool);

        // CEAL (fresh component runs)
        let ceal = Ceal::new(CealParams::no_hist());
        let (mut r1, mut r2) = pair(4);
        let old = legacy::run_ceal(&ceal, &prob, &pool, &scorer, m, &mut r1);
        let new = ceal.run(&prob, &pool, &scorer, m, &mut r2);
        assert_outputs_identical(&format!("CEAL/{wf}"), &old, &new, &pool);

        // CEAL + historical component measurements
        let hist = Arc::new(historical_samples(&prob, 60, seed ^ 0x415));
        let ceal_h = Ceal::with_historical(CealParams::with_hist(), Arc::clone(&hist));
        let (mut r1, mut r2) = pair(5);
        let old = legacy::run_ceal(&ceal_h, &prob, &pool, &scorer, m, &mut r1);
        let new = ceal_h.run(&prob, &pool, &scorer, m, &mut r2);
        assert_outputs_identical(&format!("CEAL+hist/{wf}"), &old, &new, &pool);

        // ALpH (and its hist variant shares the same loop body)
        let alph = Alph::new(CealParams::no_hist());
        let (mut r1, mut r2) = pair(6);
        let old = legacy::run_alph(&alph, &prob, &pool, &scorer, m, &mut r1);
        let new = alph.run(&prob, &pool, &scorer, m, &mut r2);
        assert_outputs_identical(&format!("ALpH/{wf}"), &old, &new, &pool);

        let alph_h = Alph::with_historical(CealParams::with_hist(), hist);
        let (mut r1, mut r2) = pair(7);
        let old = legacy::run_alph(&alph_h, &prob, &pool, &scorer, m, &mut r1);
        let new = alph_h.run(&prob, &pool, &scorer, m, &mut r2);
        assert_outputs_identical(&format!("ALpH+hist/{wf}"), &old, &new, &pool);

        // budgeted CEAL (cost budget in objective units)
        let budgeted = BudgetedCeal::new(BudgetedCealParams::default());
        let budget = 60.0 * prob.objective.value(&prob.sim.expected(&pool.configs[0])).max(1.0);
        let (mut r1, mut r2) = pair(8);
        let old = legacy::run_budgeted(&budgeted, &prob, &pool, &scorer, budget, &mut r1);
        let new = budgeted.run_with_cost_budget(&prob, &pool, &scorer, budget, &mut r2);
        assert_outputs_identical(&format!("budgeted/{wf}"), &old, &new, &pool);
    }
}

/// Replay must reproduce a recorded session exactly: identical output,
/// every recorded batch consumed, no simulator involved the second
/// time.
#[test]
fn replay_equals_record() {
    for (tuner, stream) in [
        (
            Box::new(Ceal::new(CealParams::no_hist())) as Box<dyn Tuner>,
            21u64,
        ),
        (Box::new(Geist::default()) as Box<dyn Tuner>, 22),
    ] {
        let prob = Problem::new(WorkflowId::LV, Objective::CompTime);
        let pool = Pool::generate(&prob, 100, 77);
        let scorer = Scorer::Native;
        let m = 18;
        let header = TraceHeader {
            algo: tuner.name().into(),
            workflow: "LV".into(),
            objective: "comp_time".into(),
            m,
            pool_size: 100,
            seed: 77,
            scorer: "native".into(),
            ceal_params: None,
            faults: None,
        };

        // record against the simulator collector
        let mut rng = Pcg32::new(77, stream);
        let mut col = Collector::new(&prob, rng.derive_str("collector"));
        let mut buf: Vec<u8> = Vec::new();
        let mut recorder = TraceRecorder::new(&mut col, &mut buf, &header).unwrap();
        let recorded = drive(
            tuner.session(&prob, &pool, &scorer, m, &mut rng),
            &mut recorder,
        );
        recorder.finish().unwrap();

        // replay from the trace alone
        let text = String::from_utf8(buf).unwrap();
        let mut replayer = TraceReplayer::parse(&text).unwrap();
        assert_eq!(replayer.header.algo, tuner.name());
        let mut rng2 = Pcg32::new(77, stream);
        let replayed = drive(
            tuner.session(&prob, &pool, &scorer, m, &mut rng2),
            &mut replayer,
        );
        assert_eq!(replayer.remaining(), 0, "{}: unconsumed batches", tuner.name());
        assert_outputs_identical(
            &format!("replay/{}", tuner.name()),
            &recorded,
            &replayed,
            &pool,
        );

        // and the recorded path itself equals a plain simulator run
        let mut rng3 = Pcg32::new(77, stream);
        let direct = tuner.run(&prob, &pool, &scorer, m, &mut rng3);
        assert_outputs_identical(
            &format!("record/{}", tuner.name()),
            &direct,
            &recorded,
            &pool,
        );
    }
}

/// A problem whose pool was generated on the real machine but whose
/// component spaces were made infeasible afterwards: sessions must
/// *surface* the warning on the chosen sink instead of printing it.
fn infeasible_component_problem() -> (Problem, Pool) {
    let prob = Problem::new(WorkflowId::LV, Objective::CompTime);
    let pool = Pool::generate(&prob, 80, 909);
    let mut prob = prob;
    // no allocation fits any more: every isolated-run sample errors,
    // while workflow measurements (which never re-check feasibility)
    // still run
    prob.sim.machine.max_nodes = 0;
    (prob, pool)
}

#[test]
fn infeasible_warnings_are_captured_not_printed() {
    let (prob, pool) = infeasible_component_problem();
    let scorer = Scorer::Native;

    // CEAL session with a capturing sink
    let tuner = Ceal::new(CealParams::no_hist());
    let mut rng = Pcg32::new(5, 5);
    let mut session = tuner.session(&prob, &pool, &scorer, 15, &mut rng);
    session.set_diag_sink(DiagSink::Capture);
    let mut col = Collector::new(&prob, Pcg32::new(6, 6));
    loop {
        let batch = session.ask();
        if batch.is_empty() {
            break;
        }
        let results = col.evaluate(&batch);
        session.tell(&results);
    }
    let diags = session.diagnostics();
    assert!(!diags.is_empty(), "infeasible spaces must surface a warning");
    assert!(
        diags[0].contains("no feasible configuration"),
        "warning should carry the cause: {}",
        diags[0]
    );
    assert!(
        diags[0].contains("skipping its isolated runs"),
        "warning should carry the consequence: {}",
        diags[0]
    );
    // the campaign still completes on workflow data alone
    let out = session.finish();
    assert!(out.best_idx < pool.len());
    assert!(out.workflow_runs > 0);

    // silent sink: nothing captured, session still completes
    let mut rng = Pcg32::new(7, 7);
    let mut session = tuner.session(&prob, &pool, &scorer, 15, &mut rng);
    session.set_diag_sink(DiagSink::Silent);
    let mut col = Collector::new(&prob, Pcg32::new(8, 8));
    loop {
        let batch = session.ask();
        if batch.is_empty() {
            break;
        }
        let results = col.evaluate(&batch);
        session.tell(&results);
    }
    assert!(session.diagnostics().is_empty(), "silent sink must not capture");

    // budgeted CEAL surfaces the same warnings through its sink
    let budgeted = BudgetedCeal::new(BudgetedCealParams::default());
    let mut rng = Pcg32::new(9, 9);
    let mut session = budgeted.session_with_cost_budget(&prob, &pool, &scorer, 200.0, &mut rng);
    session.set_diag_sink(DiagSink::Capture);
    let mut col = Collector::new(&prob, Pcg32::new(10, 10));
    loop {
        let batch = session.ask();
        if batch.is_empty() {
            break;
        }
        let results = col.evaluate(&batch);
        session.tell(&results);
    }
    // one warning per configurable component (each skips only itself)
    assert_eq!(
        session.diagnostics().len(),
        prob.sim.spec.configurable().len(),
        "budgeted: one warning per infeasible component"
    );
}

/// The ALpH session shares CEAL's phase-1; its warnings route through
/// the same sink.
#[test]
fn alph_warnings_are_captured() {
    let (prob, pool) = infeasible_component_problem();
    let tuner = Alph::new(CealParams::no_hist());
    let mut rng = Pcg32::new(11, 11);
    let mut session = tuner.session(&prob, &pool, &Scorer::Native, 15, &mut rng);
    session.set_diag_sink(DiagSink::Capture);
    let mut col = Collector::new(&prob, Pcg32::new(12, 12));
    loop {
        let batch = session.ask();
        if batch.is_empty() {
            break;
        }
        let results = col.evaluate(&batch);
        session.tell(&results);
    }
    assert!(!session.diagnostics().is_empty());
}
