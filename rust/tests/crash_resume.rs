//! Crash-safety pinning for journaled sessions: killing a
//! `--checkpoint-dir` run at *any* interruption point (after the ask
//! was journaled, after the tell was journaled, after the tell was
//! applied) and resuming from disk must produce a `TunerOutput`
//! bit-identical to the uninterrupted run — for every algorithm, with
//! and without fault injection, across the workflow registry.  Also
//! pins the recovery semantics of damaged checkpoints: a torn final
//! record is dropped and re-measured, corruption anywhere else is a
//! structured `TraceError`, never a panic.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use ceal::config::WorkflowId;
use ceal::coordinator::historical_samples;
use ceal::sim::Objective;
use ceal::surrogate::Scorer;
use ceal::tuner::{
    drive, drive_checkpointed, load_checkpoint, replay_into, ActiveLearning, Alph, BudgetedCeal,
    BudgetedCealParams, Ceal, CealParams, Collector, Evaluator, FailurePolicy, FaultInjector,
    FaultPlan, Geist, Pool, Problem, RandomSampling, SessionJournal, TraceError, TraceHeader,
    Tuner, TunerOutput, TunerSession, JOURNAL_FILE,
};
use ceal::util::rng::Pcg32;

/// Unique temp dir per test case (tests run in one process, so the
/// pid alone is not enough).
fn checkpoint_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("ceal-crash-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn header_for(tuner: &dyn Tuner, wf: WorkflowId, obj: Objective, m: usize, seed: u64) -> TraceHeader {
    TraceHeader {
        algo: tuner.name().into(),
        workflow: wf.name().into(),
        objective: obj.name().into(),
        m,
        pool_size: 0,
        seed,
        scorer: "native".into(),
        ceal_params: None,
        faults: None,
    }
}

/// The bit-identity check: trajectory, searcher pick, accounting and
/// the trained model itself.
fn assert_identical(label: &str, a: &TunerOutput, b: &TunerOutput) {
    assert_eq!(a.measured, b.measured, "{label}: measured trajectories diverge");
    assert_eq!(a.best_idx, b.best_idx, "{label}: searcher picks diverge");
    assert_eq!(
        a.collection_cost.to_bits(),
        b.collection_cost.to_bits(),
        "{label}: collection cost diverges ({} vs {})",
        a.collection_cost,
        b.collection_cost
    );
    assert_eq!(a.workflow_runs, b.workflow_runs, "{label}: run counts diverge");
    assert_eq!(a.failed_runs, b.failed_runs, "{label}: failure counts diverge");
    assert_eq!(a.model, b.model, "{label}: final models diverge");
}

/// Drive a journaled session and abandon it mid-flight, simulating a
/// kill during exchange `kill_at` at one of three interruption points:
/// 0 = right after the ask was journaled (measurement lost mid-air),
/// 1 = right after the tell was journaled but before the session saw
/// it, 2 = right after the tell was applied.  Returns the number of
/// exchanges fully applied before the kill.
fn drive_until_kill(
    mut session: Box<dyn TunerSession + '_>,
    evaluator: &mut dyn Evaluator,
    journal: &mut SessionJournal,
    kill_at: usize,
    flavor: usize,
) -> usize {
    let mut k = 0;
    loop {
        let batch = session.ask();
        if batch.is_empty() {
            return k; // finished before the kill point
        }
        journal.record_ask(&batch);
        if k == kill_at && flavor == 0 {
            return k;
        }
        let results = evaluator.evaluate(&batch);
        journal.record_tell(&results, evaluator.checkpoint_state());
        if k == kill_at && flavor == 1 {
            return k;
        }
        session.tell(&results);
        journal.after_apply(session.digest());
        if k == kill_at {
            return k + 1;
        }
        k += 1;
    }
}

/// Shared fixture: one (tuner, cell, fault) scenario.  All runs —
/// reference, killed, resumed — construct RNG, collector and session
/// in exactly the campaign's order.
struct Scenario<'a> {
    tuner: &'a dyn Tuner,
    prob: &'a Problem,
    pool: &'a Pool,
    wf: WorkflowId,
    obj: Objective,
    m: usize,
    seed: u64,
    stream: u64,
    faults: Option<(FaultPlan, u64)>,
}

impl Scenario<'_> {
    fn rng(&self) -> Pcg32 {
        Pcg32::new(self.seed, self.stream)
    }

    /// The uninterrupted plain run this whole suite compares against.
    fn reference(&self) -> TunerOutput {
        let mut rng = self.rng();
        let mut col = Collector::new(self.prob, rng.derive_str("collector"));
        let mut session = self
            .tuner
            .session(self.prob, self.pool, &Scorer::Native, self.m, &mut rng);
        match self.faults {
            Some((plan, fseed)) => {
                session.set_failure_policy(FailurePolicy::fault_tolerant());
                let mut inj = FaultInjector::new(&mut col, plan, fseed);
                drive(session, &mut inj)
            }
            None => drive(session, &mut col),
        }
    }

    /// Journal an uninterrupted run into `dir` (to learn the exchange
    /// count and pin journaling-changes-nothing).
    fn journaled(&self, dir: &Path) -> (TunerOutput, usize) {
        let header = header_for(self.tuner, self.wf, self.obj, self.m, self.seed);
        let mut journal = SessionJournal::create(dir, &header, 0).unwrap();
        journal.set_snapshot_every(3);
        let mut rng = self.rng();
        let mut col = Collector::new(self.prob, rng.derive_str("collector"));
        let mut session = self
            .tuner
            .session(self.prob, self.pool, &Scorer::Native, self.m, &mut rng);
        let out = match self.faults {
            Some((plan, fseed)) => {
                session.set_failure_policy(FailurePolicy::fault_tolerant());
                let mut inj = FaultInjector::new(&mut col, plan, fseed);
                drive_checkpointed(session, &mut inj, &mut journal)
            }
            None => drive_checkpointed(session, &mut col, &mut journal),
        };
        assert!(journal.error().is_none(), "{:?}", journal.error());
        (out, journal.exchanges())
    }

    /// Run into `dir`, get killed during exchange `kill_at` at
    /// `flavor`, then resume from disk and finish.
    fn killed_then_resumed(&self, dir: &Path, kill_at: usize, flavor: usize) -> TunerOutput {
        let _ = std::fs::remove_dir_all(dir);
        let header = header_for(self.tuner, self.wf, self.obj, self.m, self.seed);
        {
            let mut journal = SessionJournal::create(dir, &header, 0).unwrap();
            journal.set_snapshot_every(3);
            let mut rng = self.rng();
            let mut col = Collector::new(self.prob, rng.derive_str("collector"));
            let mut session = self
                .tuner
                .session(self.prob, self.pool, &Scorer::Native, self.m, &mut rng);
            match self.faults {
                Some((plan, fseed)) => {
                    session.set_failure_policy(FailurePolicy::fault_tolerant());
                    let mut inj = FaultInjector::new(&mut col, plan, fseed);
                    drive_until_kill(session, &mut inj, &mut journal, kill_at, flavor);
                }
                None => {
                    drive_until_kill(session, &mut col, &mut journal, kill_at, flavor);
                }
            }
            assert!(journal.error().is_none(), "{:?}", journal.error());
            // the killed process goes away here: file handle dropped,
            // nothing flushed beyond what the journal already synced
        }
        self.resume(dir)
    }

    /// Resume a checkpoint directory and run to completion.
    fn resume(&self, dir: &Path) -> TunerOutput {
        let (mut journal, loaded) = SessionJournal::resume(dir).unwrap();
        journal.set_snapshot_every(3);
        let mut rng = self.rng();
        let mut col = Collector::new(self.prob, rng.derive_str("collector"));
        let mut session = self
            .tuner
            .session(self.prob, self.pool, &Scorer::Native, self.m, &mut rng);
        let out = match self.faults {
            Some((plan, fseed)) => {
                session.set_failure_policy(FailurePolicy::fault_tolerant());
                let mut inj = FaultInjector::new(&mut col, plan, fseed);
                replay_into(session.as_mut(), &mut inj, &loaded).unwrap();
                drive_checkpointed(session, &mut inj, &mut journal)
            }
            None => {
                replay_into(session.as_mut(), &mut col, &loaded).unwrap();
                drive_checkpointed(session, &mut col, &mut journal)
            }
        };
        assert!(journal.error().is_none(), "{:?}", journal.error());
        out
    }

    /// The full kill matrix for this scenario: journaling changes
    /// nothing, and every sampled (kill point, flavor) resumes to the
    /// reference bits.  `thorough` kills at every exchange × every
    /// flavor; otherwise kill points are sampled and flavors cycled.
    fn pin_kill_matrix(&self, tag: &str, thorough: bool) {
        let reference = self.reference();
        let dir = checkpoint_dir(tag);
        let (journaled, n) = self.journaled(&dir);
        assert_identical(&format!("{tag}/journaled"), &reference, &journaled);
        assert!(n >= 2, "{tag}: want a multi-exchange session, got {n}");
        let kill_points: Vec<usize> = if thorough {
            (0..n).collect()
        } else {
            let mut pts = vec![0, n / 3, (2 * n) / 3, n - 1];
            pts.dedup();
            pts
        };
        for kill_at in kill_points {
            let flavors: Vec<usize> = if thorough { vec![0, 1, 2] } else { vec![kill_at % 3] };
            for flavor in flavors {
                let out = self.killed_then_resumed(&dir, kill_at, flavor);
                assert_identical(
                    &format!("{tag}/kill@{kill_at}.f{flavor}"),
                    &reference,
                    &out,
                );
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}

fn all_tuners(prob: &Problem, seed: u64) -> Vec<(Box<dyn Tuner>, &'static str)> {
    let hist = Arc::new(historical_samples(prob, 60, seed ^ 0x415));
    vec![
        (Box::new(RandomSampling) as Box<dyn Tuner>, "RS"),
        (Box::new(ActiveLearning::default()), "AL"),
        (Box::new(Geist::default()), "GEIST"),
        (Box::new(Ceal::new(CealParams::no_hist())), "CEAL"),
        (
            Box::new(Ceal::with_historical(CealParams::with_hist(), Arc::clone(&hist))),
            "CEAL_hist",
        ),
        (Box::new(Alph::new(CealParams::no_hist())), "ALpH"),
        (
            Box::new(Alph::with_historical(CealParams::with_hist(), hist)),
            "ALpH_hist",
        ),
    ]
}

/// Every algorithm on the LV cell: kill, resume, compare bits.
#[test]
fn every_algorithm_survives_kills_on_lv() {
    let prob = Problem::new(WorkflowId::LV, Objective::CompTime);
    let pool = Pool::generate(&prob, 48, 0xC0DE);
    for (i, (tuner, name)) in all_tuners(&prob, 0xC0DE).into_iter().enumerate() {
        let sc = Scenario {
            tuner: tuner.as_ref(),
            prob: &prob,
            pool: &pool,
            wf: WorkflowId::LV,
            obj: Objective::CompTime,
            m: 10,
            seed: 0xC0DE,
            stream: 30 + i as u64,
            faults: None,
        };
        sc.pin_kill_matrix(&format!("lv-{name}"), false);
    }
}

/// The same matrix under 20%/5% transient fault injection: the journal
/// records the post-fault stream and the injector's attempt counters
/// fast-forward on replay, so faulted runs resume bit-identically too.
#[test]
fn every_algorithm_survives_kills_under_faults() {
    let prob = Problem::new(WorkflowId::LV, Objective::CompTime);
    let pool = Pool::generate(&prob, 48, 0xFA17);
    for (i, (tuner, name)) in all_tuners(&prob, 0xFA17).into_iter().enumerate() {
        let sc = Scenario {
            tuner: tuner.as_ref(),
            prob: &prob,
            pool: &pool,
            wf: WorkflowId::LV,
            obj: Objective::CompTime,
            m: 10,
            seed: 0xFA17,
            stream: 50 + i as u64,
            faults: Some((FaultPlan::transient(0.2, 0.05), 0xF0 + i as u64)),
        };
        sc.pin_kill_matrix(&format!("faulted-{name}"), false);
    }
}

/// The thorough cell: CEAL on LV killed after *every* exchange at
/// *every* interruption point.
#[test]
fn ceal_survives_every_kill_point_and_flavor() {
    let prob = Problem::new(WorkflowId::LV, Objective::CompTime);
    let pool = Pool::generate(&prob, 48, 0xA1);
    let tuner = Ceal::new(CealParams::no_hist());
    let sc = Scenario {
        tuner: &tuner,
        prob: &prob,
        pool: &pool,
        wf: WorkflowId::LV,
        obj: Objective::CompTime,
        m: 10,
        seed: 0xA1,
        stream: 4,
        faults: None,
    };
    sc.pin_kill_matrix("thorough-ceal", true);
}

/// The rest of the workflow registry, one algorithm per cell.
#[test]
fn kills_resume_across_the_workflow_registry() {
    let prob_seed = 0x5EED;
    let cells: Vec<(WorkflowId, Objective, Box<dyn Tuner>, &str)> = vec![
        (
            WorkflowId::HS,
            Objective::ExecTime,
            Box::new(ActiveLearning::default()) as Box<dyn Tuner>,
            "hs-AL",
        ),
        (
            WorkflowId::GP,
            Objective::CompTime,
            Box::new(Geist::default()),
            "gp-GEIST",
        ),
        (
            WorkflowId::CH5,
            Objective::ExecTime,
            Box::new(Alph::new(CealParams::no_hist())),
            "ch5-ALpH",
        ),
        (
            WorkflowId::DM4,
            Objective::ExecTime,
            Box::new(Ceal::new(CealParams::no_hist())),
            "dm4-CEAL",
        ),
    ];
    for (k, (wf, obj, tuner, tag)) in cells.into_iter().enumerate() {
        let prob = Problem::new(wf, obj);
        let pool = Pool::generate(&prob, 48, prob_seed + k as u64);
        let sc = Scenario {
            tuner: tuner.as_ref(),
            prob: &prob,
            pool: &pool,
            wf,
            obj,
            m: 10,
            seed: prob_seed + k as u64,
            stream: 70 + k as u64,
            faults: None,
        };
        sc.pin_kill_matrix(tag, false);
    }
}

/// Budgeted CEAL journals through the same machinery; its sessions are
/// built with a cost budget instead of a sample budget.
#[test]
fn budgeted_ceal_survives_kills() {
    let prob = Problem::new(WorkflowId::LV, Objective::CompTime);
    let pool = Pool::generate(&prob, 48, 0xB06);
    let budgeted = BudgetedCeal::new(BudgetedCealParams::default());
    let budget = 40.0 * prob.objective.value(&prob.sim.expected(&pool.configs[0])).max(1.0);
    let header = TraceHeader {
        algo: "budgeted".into(),
        workflow: "LV".into(),
        objective: "comp_time".into(),
        m: 0,
        pool_size: 0,
        seed: 0xB06,
        scorer: "native".into(),
        ceal_params: None,
        faults: None,
    };

    let reference = {
        let mut rng = Pcg32::new(0xB06, 9);
        let mut col = Collector::new(&prob, rng.derive_str("collector"));
        let session =
            budgeted.session_with_cost_budget(&prob, &pool, &Scorer::Native, budget, &mut rng);
        drive(session, &mut col)
    };
    let dir = checkpoint_dir("budgeted");
    // count the exchanges via an uninterrupted journaled run
    let n = {
        let mut journal = SessionJournal::create(&dir, &header, 0).unwrap();
        journal.set_snapshot_every(3);
        let mut rng = Pcg32::new(0xB06, 9);
        let mut col = Collector::new(&prob, rng.derive_str("collector"));
        let session =
            budgeted.session_with_cost_budget(&prob, &pool, &Scorer::Native, budget, &mut rng);
        let out = drive_checkpointed(session, &mut col, &mut journal);
        assert!(journal.error().is_none());
        assert_identical("budgeted/journaled", &reference, &out);
        journal.exchanges()
    };
    assert!(n >= 2, "budgeted session should take several exchanges, got {n}");
    for kill_at in [0, n / 2, n - 1] {
        for flavor in [0, 1, 2] {
            let _ = std::fs::remove_dir_all(&dir);
            {
                let mut journal = SessionJournal::create(&dir, &header, 0).unwrap();
                journal.set_snapshot_every(3);
                let mut rng = Pcg32::new(0xB06, 9);
                let mut col = Collector::new(&prob, rng.derive_str("collector"));
                let session = budgeted
                    .session_with_cost_budget(&prob, &pool, &Scorer::Native, budget, &mut rng);
                drive_until_kill(session, &mut col, &mut journal, kill_at, flavor);
                assert!(journal.error().is_none());
            }
            let (mut journal, loaded) = SessionJournal::resume(&dir).unwrap();
            journal.set_snapshot_every(3);
            let mut rng = Pcg32::new(0xB06, 9);
            let mut col = Collector::new(&prob, rng.derive_str("collector"));
            let mut session = budgeted
                .session_with_cost_budget(&prob, &pool, &Scorer::Native, budget, &mut rng);
            replay_into(session.as_mut(), &mut col, &loaded).unwrap();
            let out = drive_checkpointed(session, &mut col, &mut journal);
            assert!(journal.error().is_none(), "{:?}", journal.error());
            assert_identical(&format!("budgeted/kill@{kill_at}.f{flavor}"), &reference, &out);
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Damaged checkpoints: corruption in the middle of the journal is a
/// structured CRC error; a torn final record is crash residue — it is
/// dropped with a note and the lost measurement is simply redone.
#[test]
fn damaged_journals_fail_structurally_or_recover() {
    let prob = Problem::new(WorkflowId::LV, Objective::CompTime);
    let pool = Pool::generate(&prob, 48, 0xDA4A);
    let tuner = Ceal::new(CealParams::no_hist());
    let sc = Scenario {
        tuner: &tuner,
        prob: &prob,
        pool: &pool,
        wf: WorkflowId::LV,
        obj: Objective::CompTime,
        m: 10,
        seed: 0xDA4A,
        stream: 8,
        faults: None,
    };
    let reference = sc.reference();
    let dir = checkpoint_dir("damaged");

    // fixture: an uninterrupted journaled run with compaction held
    // off, so the journal file itself holds every record
    let journal_fixture = || {
        let _ = std::fs::remove_dir_all(&dir);
        let header = header_for(&tuner, WorkflowId::LV, Objective::CompTime, 10, 0xDA4A);
        let mut journal = SessionJournal::create(&dir, &header, 0).unwrap();
        journal.set_snapshot_every(100_000);
        let mut rng = Pcg32::new(0xDA4A, 8);
        let mut col = Collector::new(&prob, rng.derive_str("collector"));
        let session = tuner.session(&prob, &pool, &Scorer::Native, 10, &mut rng);
        let out = drive_checkpointed(session, &mut col, &mut journal);
        assert!(journal.error().is_none());
        out
    };

    // corrupt a record in the middle of the journal -> hard CRC error
    journal_fixture();
    let path = dir.join(JOURNAL_FILE);
    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert!(lines.len() > 3, "journal should hold several records");
    let mut damaged: Vec<String> = lines.iter().map(|l| l.to_string()).collect();
    damaged[2] = damaged[2]
        .chars()
        .map(|c| if c.is_ascii_digit() { '9' } else { c })
        .collect();
    std::fs::write(&path, format!("{}\n", damaged.join("\n"))).unwrap();
    match load_checkpoint(&dir) {
        Err(TraceError::Crc { .. }) | Err(TraceError::Malformed(_)) => {}
        other => panic!("corrupt middle record must be a structured error, got {other:?}"),
    }

    // garbage bytes instead of a journal -> structured, not a panic
    std::fs::write(&path, b"\x00\xff\x00 not a journal\n").unwrap();
    assert!(
        load_checkpoint(&dir).is_err(),
        "garbage journal must be an error"
    );

    // torn final record: recovered note + the run completes to the
    // reference bits (the dropped record is re-measured live)
    let fixture_out = journal_fixture();
    assert_identical("damaged/fixture", &reference, &fixture_out);
    let text = std::fs::read_to_string(&path).unwrap();
    let cut = text.trim_end().rfind('\n').unwrap();
    // keep half of the final record: a torn write, as after a crash
    let keep = cut + (text.len() - cut) / 2;
    std::fs::write(&path, &text.as_bytes()[..keep]).unwrap();
    let loaded = load_checkpoint(&dir).unwrap();
    assert!(
        !loaded.recovered.is_empty(),
        "a torn final record must surface a recovery note"
    );
    let out = sc.resume(&dir);
    assert_identical("torn-final", &reference, &out);
    let _ = std::fs::remove_dir_all(&dir);
}
