//! Protocol-semantics pinning for the serve subsystem, driven entirely
//! in-process through [`Loopback`] (the same line codec TCP carries):
//!
//! - re-asks are idempotent (same seq, same batch — no session panic);
//! - a tell for an already-answered seq is acknowledged as a duplicate
//!   and NOT re-applied (the final trajectory stays bit-identical to a
//!   serial `drive()`), pinned for all seven algorithms on LV;
//! - a tell for a seq the session never issued is a structured
//!   `unknown-request` error; wrong arity is a structured `usage`
//!   error; a bogus token is `unknown-token` — never a dropped
//!   conversation or a panic;
//! - idle sessions evict to disk and lazily rehydrate with no effect
//!   on the trajectory; a manager "killed" between an ask and its tell
//!   re-materializes the in-flight batch after restart, so the tell
//!   applies without a re-ask;
//! - per-session diagnostics land in the session's own `diag.log`.

use std::path::PathBuf;
use std::time::Duration;

use ceal::config::WorkflowId;
use ceal::coordinator::{session_rng, tuner_for, Algo, PoolCache, ScorerKind};
use ceal::serve::{Loopback, OpenSpec, ServeClient, ServeError, SessionManager};
use ceal::sim::Objective;
use ceal::tuner::{drive, Collector, Evaluator, Problem, TunerOutput};
use ceal::util::json::Json;

fn temp_root(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("ceal-serveproto-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

const M: usize = 6;
const POOL: usize = 64;
const SEED: u64 = 0xA11;

fn spec_for(algo: Algo) -> OpenSpec {
    OpenSpec {
        workflow: "LV".into(),
        objective: "comp".into(),
        algo: algo.name().into(),
        m: M,
        pool_size: POOL,
        seed: SEED,
        scorer: "native".into(),
    }
}

/// The uninterrupted local reference: the exact construction `ceal
/// tune --checkpoint-dir` (and the daemon) uses, driven serially.
fn serial_drive(algo: Algo) -> TunerOutput {
    let prob = Problem::new(WorkflowId::LV, Objective::CompTime);
    let pool = PoolCache::global()
        .try_get_or_generate(&prob, POOL, SEED, 2)
        .expect("LV pool");
    let scorer = ScorerKind::Native.build();
    let tuner = tuner_for(algo, &prob, SEED, None);
    let mut rng = session_rng(SEED, algo, 0);
    let mut col = Collector::new(&prob, rng.derive_str("collector"));
    let session = tuner.session(&prob, &pool, &scorer, M, &mut rng);
    drive(session, &mut col)
}

/// The client-side evaluator, constructed exactly as `ceal client`
/// constructs it from the open response's header.
fn client_collector(prob: &Problem, algo: Algo) -> Collector<'_> {
    let mut rng = session_rng(SEED, algo, 0);
    Collector::new(prob, rng.derive_str("collector"))
}

fn assert_payload_matches(label: &str, payload: &Json, reference: &TunerOutput) {
    assert_eq!(
        payload.get("best_idx").and_then(Json::as_usize),
        Some(reference.best_idx),
        "{label}: best_idx diverges"
    );
    let cost = payload
        .get("collection_cost")
        .and_then(Json::as_f64)
        .expect("payload collection_cost");
    assert_eq!(
        cost.to_bits(),
        reference.collection_cost.to_bits(),
        "{label}: collection cost diverges ({cost} vs {})",
        reference.collection_cost
    );
    assert_eq!(
        payload.get("workflow_runs").and_then(Json::as_usize),
        Some(reference.workflow_runs),
        "{label}: workflow_runs diverges"
    );
    assert_eq!(
        payload.get("failed_runs").and_then(Json::as_usize),
        Some(reference.failed_runs),
        "{label}: failed_runs diverges"
    );
    assert_eq!(
        payload.get("measured").and_then(Json::as_usize),
        Some(reference.measured.len()),
        "{label}: measured count diverges"
    );
}

/// Duplicate and out-of-order tells, re-ask idempotency and arity
/// checking, pinned against the serial reference for every registered
/// algorithm.
#[test]
fn perturbed_tells_stay_bit_identical_for_all_algorithms() {
    let root = temp_root("perturb");
    let mgr = SessionManager::new(&root, 2, None).unwrap();
    for &algo in Algo::ALL.iter() {
        let label = algo.name();
        let prob = Problem::new(WorkflowId::LV, Objective::CompTime);
        let mut col = client_collector(&prob, algo);
        let mut client = ServeClient::new(Loopback(&mgr));
        let info = client.open(&spec_for(algo)).unwrap();
        assert!(!info.resumed);

        // a tell before any ask names no known request
        match client.tell(0, &[], None) {
            Err(ServeError::Remote { kind, code, .. }) => {
                assert_eq!(kind, "unknown-request", "{label}");
                assert_eq!(code, 1, "{label}");
            }
            other => panic!("{label}: want unknown-request, got {other:?}"),
        }

        loop {
            let a1 = client.ask().unwrap();
            if a1.done {
                break;
            }
            // re-ask is idempotent: same seq, same batch
            let a2 = client.ask().unwrap();
            assert_eq!(a1.seq, a2.seq, "{label}: re-ask changed seq");
            assert_eq!(a1.batch, a2.batch, "{label}: re-ask changed batch");
            let batch = a1.batch.unwrap();
            let results = col.evaluate(&batch);
            // a tell for a seq that was never asked is refused
            match client.tell(a1.seq + 7, &results, None) {
                Err(ServeError::Remote { kind, .. }) => {
                    assert_eq!(kind, "unknown-request", "{label}")
                }
                other => panic!("{label}: want unknown-request, got {other:?}"),
            }
            // wrong arity on the right seq is refused, not applied
            if results.len() > 1 {
                match client.tell(a1.seq, &results[..1], None) {
                    Err(ServeError::Remote { kind, .. }) => assert_eq!(kind, "usage", "{label}"),
                    other => panic!("{label}: want usage error, got {other:?}"),
                }
            }
            let eval = col.checkpoint_state();
            let r = client.tell(a1.seq, &results, eval.as_ref()).unwrap();
            assert!(r.applied, "{label}: tell not applied");
            // re-telling the answered seq is a duplicate ack, not a
            // second application
            let d = client.tell(a1.seq, &results, None).unwrap();
            assert!(d.duplicate, "{label}: duplicate tell not acknowledged");
            assert!(!d.applied, "{label}: duplicate tell re-applied");
            if r.done {
                break;
            }
        }
        let payload = client.finish().unwrap();
        assert_payload_matches(label, &payload, &serial_drive(algo));
        // finish is idempotent: the sealed artifact answers repeats
        let again = client.finish().unwrap();
        assert_eq!(
            again.get("best_idx").and_then(Json::as_usize),
            payload.get("best_idx").and_then(Json::as_usize),
            "{label}: repeated finish diverges"
        );
    }
    let _ = std::fs::remove_dir_all(&root);
}

/// Idle eviction mid-session is invisible to the trajectory: evict
/// after every exchange (TTL ~ 0), rehydrate lazily on the next verb,
/// finish bit-identical to the serial reference.
#[test]
fn eviction_and_rehydration_mid_session_change_nothing() {
    let root = temp_root("evict");
    let ttl = Duration::from_millis(1);
    let mgr = SessionManager::new(&root, 2, Some(ttl)).unwrap();
    let algo = Algo::Ceal;
    let prob = Problem::new(WorkflowId::LV, Objective::CompTime);
    let mut col = client_collector(&prob, algo);
    let mut client = ServeClient::new(Loopback(&mgr));
    client.open(&spec_for(algo)).unwrap();
    let mut evictions = 0;
    loop {
        let ask = client.ask().unwrap();
        if ask.done {
            break;
        }
        let batch = ask.batch.unwrap();
        let results = col.evaluate(&batch);
        let eval = col.checkpoint_state();
        let r = client.tell(ask.seq, &results, eval.as_ref()).unwrap();
        // idle long enough for the TTL, then force a sweep: the
        // tenant's in-memory half drops, the journal stays
        std::thread::sleep(Duration::from_millis(3));
        evictions += mgr.sweep();
        if r.done {
            break;
        }
    }
    assert!(evictions > 0, "sweep never evicted the idle session");
    let payload = client.finish().unwrap();
    assert_payload_matches("evict/rehydrate", &payload, &serial_drive(algo));
    let _ = std::fs::remove_dir_all(&root);
}

/// Kill the daemon between an ask and its tell: a new manager on the
/// same root must re-materialize the in-flight batch from the journal
/// so the held tell applies with no re-ask, and the session must still
/// finish bit-identical.
#[test]
fn pending_ask_survives_manager_restart() {
    let root = temp_root("pending");
    let algo = Algo::Alph;
    let prob = Problem::new(WorkflowId::LV, Objective::CompTime);
    let mut col = client_collector(&prob, algo);

    let mgr = SessionManager::new(&root, 2, None).unwrap();
    let mut client = ServeClient::new(Loopback(&mgr));
    client.open(&spec_for(algo)).unwrap();
    let token = client.token().unwrap().to_string();
    // first exchange completes normally; the second ask is left
    // hanging when the "daemon" dies
    let a = client.ask().unwrap();
    let results = col.evaluate(a.batch.as_ref().unwrap());
    client
        .tell(a.seq, &results, col.checkpoint_state().as_ref())
        .unwrap();
    let held = client.ask().unwrap();
    assert!(!held.done, "session finished before the kill point");
    let held_batch = held.batch.clone().unwrap();
    drop(client);
    drop(mgr); // SIGKILL equivalent: in-memory state is gone

    let mgr = SessionManager::new(&root, 2, None).unwrap();
    let mut client = ServeClient::new(Loopback(&mgr));
    let info = client.reopen(&token).unwrap();
    assert!(info.resumed);
    assert!(!info.done);
    // restore the client-side noise stream exactly as `ceal client`
    // does on resume
    if let Some(eval) = &info.eval {
        col.restore_state(eval);
    }
    // tell the held batch FIRST — no re-ask — proving the journal
    // re-materialized the in-flight request
    let results = col.evaluate(&held_batch);
    let r = client
        .tell(held.seq, &results, col.checkpoint_state().as_ref())
        .unwrap();
    assert!(r.applied, "held tell not applied after restart");
    // drive the remainder normally
    loop {
        let ask = client.ask().unwrap();
        if ask.done {
            break;
        }
        let results = col.evaluate(ask.batch.as_ref().unwrap());
        let r = client
            .tell(ask.seq, &results, col.checkpoint_state().as_ref())
            .unwrap();
        if r.done {
            break;
        }
    }
    let payload = client.finish().unwrap();
    assert_payload_matches("pending-ask restart", &payload, &serial_drive(algo));
    let _ = std::fs::remove_dir_all(&root);
}

/// Unknown tokens are structured errors with the documented kind, on
/// every token-bearing verb.
#[test]
fn unknown_token_is_structured_on_every_verb() {
    let root = temp_root("unknown");
    let mgr = SessionManager::new(&root, 1, None).unwrap();
    for line in [
        r#"{"verb":"open","token":"s424242"}"#,
        r#"{"verb":"ask","token":"s424242"}"#,
        r#"{"verb":"tell","token":"s424242","seq":0,"ys":[]}"#,
        r#"{"verb":"state","token":"s424242"}"#,
        r#"{"verb":"close","token":"s424242"}"#,
    ] {
        let resp = mgr.handle_line(line);
        assert!(resp.contains("\"ok\":false"), "{line} -> {resp}");
        assert!(resp.contains("unknown-token"), "{line} -> {resp}");
    }
    let _ = std::fs::remove_dir_all(&root);
}

/// Satellite: per-session diagnostics go to the session's own journal
/// directory, not a shared stderr.  A daemon that crashed mid-append
/// leaves a torn final journal record; on rehydration the recovery
/// note must land in that session's `diag.log` — and the session must
/// still finish bit-identical to the serial reference.
#[test]
fn recovery_diagnostics_land_in_the_sessions_diag_log() {
    let root = temp_root("diag");
    let algo = Algo::Ceal;
    let prob = Problem::new(WorkflowId::LV, Objective::CompTime);
    let mut col = client_collector(&prob, algo);
    let token;
    {
        let mgr = SessionManager::new(&root, 2, None).unwrap();
        let mut client = ServeClient::new(Loopback(&mgr));
        client.open(&spec_for(algo)).unwrap();
        token = client.token().unwrap().to_string();
        let a = client.ask().unwrap();
        let results = col.evaluate(a.batch.as_ref().unwrap());
        client
            .tell(a.seq, &results, col.checkpoint_state().as_ref())
            .unwrap();
    } // daemon "dies"
    // crash artifact: a half-written record at the journal tail
    {
        use std::io::Write as _;
        let jpath = root.join(&token).join(ceal::tuner::JOURNAL_FILE);
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(&jpath)
            .unwrap();
        write!(f, "{{\"type\":\"ask\",\"seq\":").unwrap();
    }
    let mgr = SessionManager::new(&root, 2, None).unwrap();
    let mut client = ServeClient::new(Loopback(&mgr));
    let info = client.reopen(&token).unwrap();
    assert!(info.resumed);
    let diag = std::fs::read_to_string(root.join(&token).join("diag.log"))
        .expect("diag.log missing from the session directory");
    assert!(
        diag.contains("torn final journal record"),
        "recovery note missing from diag.log: {diag:?}"
    );
    if let Some(eval) = &info.eval {
        col.restore_state(eval);
    }
    loop {
        let ask = client.ask().unwrap();
        if ask.done {
            break;
        }
        let results = col.evaluate(ask.batch.as_ref().unwrap());
        let r = client
            .tell(ask.seq, &results, col.checkpoint_state().as_ref())
            .unwrap();
        if r.done {
            break;
        }
    }
    let payload = client.finish().unwrap();
    assert_payload_matches("diag/torn-record", &payload, &serial_drive(algo));
    let _ = std::fs::remove_dir_all(&root);
}
