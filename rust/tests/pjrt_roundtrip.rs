//! Integration: the AOT artifacts loaded via PJRT must agree with the
//! native Rust evaluation of the same flattened ensembles — this pins
//! the whole L1 (Pallas) / L2 (JAX) / L3 (Rust) stack together.
//!
//! Requires `make artifacts` (skipped with a message otherwise).

use ceal::config::{lv_spec, Config, F_MAX};
use ceal::gbt::{train, GbtParams};
use ceal::runtime::Runtime;
use ceal::sim::Objective;
use ceal::surrogate::{PoolFeatures, Scorer};
use ceal::util::rng::Pcg32;

fn runtime_or_skip() -> Option<Runtime> {
    match Runtime::load_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP pjrt tests: {e:#} (run `make artifacts`)");
            None
        }
    }
}

fn random_rows(rng: &mut Pcg32, n: usize) -> Vec<[f32; F_MAX]> {
    (0..n)
        .map(|_| {
            let mut x = [0f32; F_MAX];
            for v in x.iter_mut() {
                *v = rng.f32();
            }
            x
        })
        .collect()
}

fn trained_ensemble(rng: &mut Pcg32, n: usize, nf: usize) -> ceal::gbt::Ensemble {
    let xs = random_rows(rng, n);
    let y: Vec<f64> = xs
        .iter()
        .map(|x| 3.0 * x[0] as f64 - 2.0 * x[1] as f64 + (x[2] as f64).powi(2))
        .collect();
    train(&xs, &y, nf, &GbtParams::default())
}

#[test]
fn ensemble_scoring_matches_native() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Pcg32::new(100, 0);
    let ens = trained_ensemble(&mut rng, 300, 4);
    for n in [1usize, 17, 256, 1000, 2048] {
        let xs = random_rows(&mut rng, n);
        let got = rt.score(&ens.flatten(), &xs).unwrap();
        assert_eq!(got.len(), n);
        for (i, x) in xs.iter().enumerate() {
            let want = ens.predict(x);
            assert!(
                (got[i] - want).abs() < 1e-3 * (1.0 + want.abs()),
                "n={n} row {i}: pjrt {} vs native {}",
                got[i],
                want
            );
        }
    }
}

#[test]
fn oversized_batch_is_slabbed() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Pcg32::new(101, 0);
    let ens = trained_ensemble(&mut rng, 100, 3);
    let xs = random_rows(&mut rng, 2048 + 300);
    let got = rt.score(&ens.flatten(), &xs).unwrap();
    assert_eq!(got.len(), xs.len());
    for (i, x) in xs.iter().enumerate().step_by(97) {
        let want = ens.predict(x);
        assert!((got[i] - want).abs() < 1e-3 * (1.0 + want.abs()));
    }
}

#[test]
fn lowfi_artifact_matches_native_combination() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Pcg32::new(102, 0);
    let e0 = trained_ensemble(&mut rng, 200, 4);
    let e1 = trained_ensemble(&mut rng, 200, 3);
    let n = 500;
    let xs0 = random_rows(&mut rng, n);
    let xs1 = random_rows(&mut rng, n);
    for (mode, name) in [(1.0f32, "max"), (0.0f32, "sum")] {
        let got = rt
            .lowfi_score(
                &[(e0.flatten(), xs0.as_slice()), (e1.flatten(), xs1.as_slice())],
                mode,
            )
            .unwrap();
        assert_eq!(got.len(), n);
        for i in (0..n).step_by(31) {
            // log-space semantics: artifact combines exp(P_j); padding
            // components contribute exp(NEG_PRED) == 0
            let p0 = (e0.predict(&xs0[i]) as f64).exp();
            let p1 = (e1.predict(&xs1[i]) as f64).exp();
            let want = if mode == 1.0 { p0.max(p1) } else { p0 + p1 };
            assert!(
                (got[i] as f64 - want).abs() < 1e-3 * (1.0 + want.abs()),
                "{name} row {i}: pjrt {} vs native {}",
                got[i],
                want
            );
        }
    }
}

#[test]
fn scorer_pjrt_equals_scorer_native_on_real_pool() {
    let Some(rt) = runtime_or_skip() else { return };
    let spec = lv_spec();
    let mut rng = Pcg32::new(103, 0);
    let configs: Vec<Config> = (0..300).map(|_| spec.sample(&mut rng)).collect();
    let feats = PoolFeatures::encode(&spec, &configs);
    let ens = trained_ensemble(&mut rng, 150, 7);

    let native = Scorer::Native.score(&ens, &feats.workflow);
    let pjrt = Scorer::Pjrt(rt).score(&ens, &feats.workflow);
    for i in 0..configs.len() {
        assert!(
            (native[i] - pjrt[i]).abs() < 1e-3 * (1.0 + native[i].abs()),
            "row {i}: {} vs {}",
            native[i],
            pjrt[i]
        );
    }
}

#[test]
fn scorer_lowfi_pjrt_equals_native() {
    let Some(rt) = runtime_or_skip() else { return };
    let spec = lv_spec();
    let mut rng = Pcg32::new(104, 0);
    let configs: Vec<Config> = (0..200).map(|_| spec.sample(&mut rng)).collect();
    let feats = PoolFeatures::encode(&spec, &configs);
    // component models trained on positive targets (times)
    let mk = |rng: &mut Pcg32, xs: &Vec<[f32; F_MAX]>, nf: usize| {
        let y: Vec<f64> = xs.iter().map(|x| 5.0 + 10.0 * x[0] as f64).collect();
        let _ = rng;
        train(xs, &y, nf, &GbtParams::small_data())
    };
    let comps = vec![
        mk(&mut rng, &feats.per_component[0], 4),
        mk(&mut rng, &feats.per_component[1], 3),
    ];
    for objective in [Objective::ExecTime, Objective::CompTime] {
        let native = Scorer::Native.lowfi(&comps, &feats, objective);
        let pjrt = Scorer::Pjrt(Runtime::load_default().unwrap()).lowfi(&comps, &feats, objective);
        let _ = &rt;
        for i in 0..configs.len() {
            assert!(
                (native[i] - pjrt[i]).abs() < 1e-3 * (1.0 + native[i].abs()),
                "{objective} row {i}: {} vs {}",
                native[i],
                pjrt[i]
            );
        }
    }
}
