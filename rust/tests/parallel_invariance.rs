//! Bitwise thread-count invariance of every parallelized path.
//!
//! The worker-pool contract (`ceal::util::parallel` module docs) is
//! that task boundaries depend only on the input and every output slot
//! has a single writer, so results are byte-identical for any
//! fork-join width.  These tests pin that for the four hot paths the
//! pool drives — GBT training, batched scoring, pool generation, and a
//! full CEAL run — across widths {1, 2, 5, 8}, plus the nested case
//! (a parallel campaign whose reps use the inner pool).
//!
//! `with_threads` scopes a process-global override; concurrent tests
//! can only perturb which width actually executes, never the outputs,
//! so the assertions hold under the parallel test harness.

use ceal::config::{WorkflowId, F_MAX};
use ceal::coordinator::{run_campaign, Algo, Campaign};
use ceal::gbt::{train, train_log, GbtParams};
use ceal::sim::Objective;
use ceal::surrogate::Scorer;
use ceal::tuner::{Ceal, CealParams, Pool, Problem};
use ceal::util::parallel::with_threads;
use ceal::util::rng::Pcg32;

const SWEEP: [usize; 4] = [1, 2, 5, 8];

fn rows(rng: &mut Pcg32, n: usize) -> Vec<[f32; F_MAX]> {
    (0..n)
        .map(|_| {
            let mut x = [0f32; F_MAX];
            for v in x.iter_mut() {
                *v = rng.f32();
            }
            x
        })
        .collect()
}

#[test]
fn train_is_thread_count_invariant() {
    let mut rng = Pcg32::new(0x7A11, 0);
    // large enough to cross every parallel gate in the trainer
    let xs = rows(&mut rng, 900);
    let y: Vec<f64> = xs
        .iter()
        .map(|x| 5.0 + 40.0 * x[0] as f64 + 10.0 * (x[1] as f64) * (x[2] as f64))
        .collect();
    let reference = with_threads(1, || train(&xs, &y, 7, &GbtParams::default()));
    let reference_log = with_threads(1, || train_log(&xs, &y, 7, &GbtParams::default()));
    for t in SWEEP {
        let got = with_threads(t, || train(&xs, &y, 7, &GbtParams::default()));
        assert_eq!(reference, got, "train diverged at {t} threads");
        let got_log = with_threads(t, || train_log(&xs, &y, 7, &GbtParams::default()));
        assert_eq!(reference_log, got_log, "train_log diverged at {t} threads");
    }
}

#[test]
fn predict_batch_is_thread_count_invariant() {
    let mut rng = Pcg32::new(0x7A12, 0);
    let xs = rows(&mut rng, 500);
    let y: Vec<f64> = xs.iter().map(|x| 1.0 + 30.0 * x[0] as f64).collect();
    let ens = train_log(&xs, &y, 6, &GbtParams::default());
    let flat = ens.flatten();
    let batch = rows(&mut rng, 2000);
    let reference = with_threads(1, || ens.predict_batch(&batch));
    let flat_reference = with_threads(1, || flat.predict_batch(&batch));
    for t in SWEEP {
        let got = with_threads(t, || ens.predict_batch(&batch));
        assert_eq!(reference, got, "predict_batch diverged at {t} threads");
        let flat_got = with_threads(t, || flat.predict_batch(&batch));
        assert_eq!(
            flat_reference, flat_got,
            "flat predict_batch diverged at {t} threads"
        );
    }
}

#[test]
fn pool_generation_is_thread_count_invariant() {
    let prob = Problem::new(WorkflowId::LV, Objective::ExecTime);
    let reference = with_threads(1, || Pool::generate_par(&prob, 150, 0x9A11, 1));
    for t in SWEEP {
        let got = with_threads(t, || Pool::generate_par(&prob, 150, 0x9A11, t));
        assert_eq!(reference.configs, got.configs, "configs diverged at {t} threads");
        assert_eq!(reference.truth(), got.truth(), "truth diverged at {t} threads");
        assert_eq!(reference.best_idx(), got.best_idx(), "best_idx diverged at {t} threads");
    }
}

/// A full CEAL run — batch measurement, low-fidelity scoring, GBT
/// retraining and full-pool selection every iteration — must be
/// bit-identical at any width: same measurements (values included),
/// same trained model, same pick, same accounted cost.
#[test]
fn ceal_run_is_thread_count_invariant() {
    let prob = Problem::new(WorkflowId::HS, Objective::CompTime);
    let pool = Pool::generate(&prob, 400, 0x9A12);
    let run_at = |t: usize| {
        with_threads(t, || {
            let mut rng = Pcg32::new(0xAB, 3);
            Ceal::new(CealParams::no_hist()).run(&prob, &pool, &Scorer::Native, 30, &mut rng)
        })
    };
    let reference = run_at(1);
    for t in SWEEP {
        let got = run_at(t);
        assert_eq!(
            reference.measured, got.measured,
            "measurements diverged at {t} threads"
        );
        assert_eq!(reference.best_idx, got.best_idx, "pick diverged at {t} threads");
        assert_eq!(reference.model, got.model, "model diverged at {t} threads");
        assert_eq!(
            reference.collection_cost, got.collection_cost,
            "cost diverged at {t} threads"
        );
        assert_eq!(reference.workflow_runs, got.workflow_runs);
    }
}

/// Nested use: campaign repetitions fan out on the pool while each
/// rep's training/scoring/measurement forks inner jobs beneath them —
/// `parallel_equals_sequential`, with the inner pool active.
#[test]
fn nested_campaign_reps_equal_sequential() {
    let base = Campaign::new(WorkflowId::LV, Objective::CompTime, 20)
        .with_reps(5)
        .with_pool_size(200)
        .with_seed(0xC0FE_D00D);
    let seq = run_campaign(Algo::Ceal, &base.with_threads(1));
    for t in [2usize, 4, 8] {
        let par = run_campaign(Algo::Ceal, &base.with_threads(t));
        assert_eq!(seq.reps.len(), par.reps.len());
        for (rep, (a, b)) in seq.reps.iter().zip(&par.reps).enumerate() {
            assert_eq!(a.best_value, b.best_value, "rep {rep} at {t} threads");
            assert_eq!(a.workflow_runs, b.workflow_runs, "rep {rep} at {t} threads");
            assert_eq!(a.cost, b.cost, "rep {rep} at {t} threads");
            assert_eq!(a.recalls, b.recalls, "rep {rep} at {t} threads");
            assert_eq!(a.mdape_all, b.mdape_all, "rep {rep} at {t} threads");
        }
    }
}

/// The collector's fan-out batch measurement keeps its determinism
/// promises: same results at any width, accounting folded in slot
/// order, and later draws from the main stream unaffected by width.
#[test]
fn measure_pool_batch_is_thread_count_invariant() {
    use ceal::tuner::Collector;
    let prob = Problem::new(WorkflowId::GP, Objective::ExecTime);
    let pool = Pool::generate(&prob, 60, 0x9A13);
    let idxs: Vec<usize> = (0..12).collect();
    let run_at = |t: usize| {
        with_threads(t, || {
            let mut col = Collector::new(&prob, Pcg32::new(0x51, 7));
            let batch = col.measure_pool_batch(&pool, &idxs);
            // a follow-up single measurement must also be unaffected
            let follow = col.measure(&pool.configs[40]);
            (batch, follow, col.total_cost(), col.workflow_runs)
        })
    };
    let reference = run_at(1);
    for t in SWEEP {
        let got = run_at(t);
        assert_eq!(reference.0, got.0, "batch diverged at {t} threads");
        assert_eq!(reference.1, got.1, "follow-up draw diverged at {t} threads");
        assert_eq!(reference.2, got.2, "cost diverged at {t} threads");
        assert_eq!(reference.3, got.3);
    }
}
