//! Trace-format stability tests: a checked-in version-1 fixture must
//! keep replaying on every future build (the current build writes
//! version 2 but reads 1..=2), and a trace written by a *newer* format
//! version must be rejected with a clear error instead of being
//! replayed into garbage results.

use std::path::PathBuf;

use ceal::config::Config;
use ceal::sim::MeasurementOutcome;
use ceal::tuner::trace::RecordedRequest;
use ceal::tuner::{
    BatchMode, Evaluator, MeasurementBatch, MeasurementRequest, TraceError, TraceReplayer,
    TRACE_VERSION,
};

fn fixture_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/session_trace_v1.jsonl")
}

fn damaged_fixture_path(kind: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join(format!("tests/fixtures/session_trace_v1_{kind}.jsonl"))
}

fn fixture_text() -> String {
    std::fs::read_to_string(fixture_path()).expect("fixture readable")
}

/// Rebuild live requests from a recorded batch (workflow requests
/// match on pool index alone; the carried config is driver payload).
fn live_requests(rec: &[RecordedRequest]) -> Vec<MeasurementRequest> {
    rec.iter()
        .map(|r| match r {
            RecordedRequest::Workflow { pool_idx } => MeasurementRequest::Workflow {
                pool_idx: *pool_idx,
                config: Config(vec![]),
            },
            RecordedRequest::Component { comp, config } => MeasurementRequest::Component {
                comp: *comp,
                config: config.clone(),
            },
        })
        .collect()
}

#[test]
fn checked_in_v1_fixture_replays() {
    assert_eq!(
        TRACE_VERSION, 2,
        "add a new fixture alongside any version bump"
    );
    let mut rep = TraceReplayer::load(&fixture_path()).expect("v1 fixture parses");
    assert_eq!(rep.header.algo, "CEAL");
    assert_eq!(rep.header.workflow, "LV");
    assert_eq!(rep.header.objective, "comp_time");
    assert_eq!(rep.header.m, 4);
    assert_eq!(rep.header.pool_size, 50);
    assert_eq!(rep.header.seed, 51905);
    assert_eq!(rep.header.scorer, "native");
    assert_eq!(rep.header.ceal_params, None);
    assert_eq!(rep.header.faults, None, "v1 traces carry no fault spec");
    assert_eq!(rep.batches().len(), 3);
    assert_eq!(rep.batches()[0].mode, BatchMode::Sequential);
    assert_eq!(rep.batches()[1].mode, BatchMode::FanOut);
    assert_eq!(
        rep.batches()[0].requests[0],
        RecordedRequest::Component {
            comp: 0,
            config: vec![430, 8, 2, 50],
        }
    );

    // serve every batch back and check the recorded values survive the
    // round-trip exactly (integral and fractional alike)
    let recorded: Vec<_> = rep.batches().to_vec();
    for batch in &recorded {
        let live = MeasurementBatch {
            mode: batch.mode,
            requests: live_requests(&batch.requests),
        };
        let results = rep.evaluate(&live);
        let outcomes: Vec<MeasurementOutcome> = results.iter().map(|r| r.outcome).collect();
        assert_eq!(outcomes, batch.outcomes);
        assert!(outcomes.iter().all(|o| o.is_ok()), "v1 ys are all numeric");
    }
    assert_eq!(rep.remaining(), 0);
    assert_eq!(rep.error(), None);
    assert_eq!(recorded[2].outcomes, [MeasurementOutcome::Ok(97.0625)]);
}

#[test]
fn bumped_version_is_rejected_with_clear_error() {
    let newer = fixture_text().replace("\"version\":1", "\"version\":3");
    assert_ne!(newer, fixture_text(), "replacement must hit");
    let err = TraceReplayer::parse(&newer).unwrap_err();
    assert_eq!(err, TraceError::Version(3));
    let msg = err.to_string();
    assert!(msg.contains("version 3"), "error names the trace version: {msg}");
    assert!(
        msg.contains("re-record"),
        "error tells the user what to do: {msg}"
    );
}

#[test]
fn non_trace_files_are_rejected() {
    assert!(TraceReplayer::parse("").is_err());
    let err = TraceReplayer::parse("{\"workflow\": \"LV\"}")
        .unwrap_err()
        .to_string();
    assert!(err.contains("ceal-session-trace"), "{err}");
    // a truncated/corrupt batch line is a parse error, not garbage
    let garbled = format!("{}{}", fixture_text(), "{\"batch\":3,\"mode\":\"seq\"\n");
    assert!(TraceReplayer::parse(&garbled).is_err());
}

/// Checked-in damaged fixtures: a trace whose final line was cut
/// mid-record (the classic crash/partial-copy artifact) and one with
/// garbage spliced into a middle record.  Both must load as structured
/// `TraceError`s that name the failing line — never a panic, never a
/// silently shortened replay.
#[test]
fn damaged_fixtures_load_as_structured_errors() {
    let truncated = std::fs::read_to_string(damaged_fixture_path("truncated")).unwrap();
    match TraceReplayer::parse(&truncated) {
        Err(TraceError::Malformed(msg)) => {
            assert!(
                msg.contains("batch line 3") || msg.contains("line 4"),
                "error should locate the torn record: {msg}"
            );
        }
        other => panic!("truncated fixture must be Malformed, got {other:?}"),
    }

    let corrupt = std::fs::read_to_string(damaged_fixture_path("corrupt")).unwrap();
    match TraceReplayer::parse(&corrupt) {
        Err(TraceError::Malformed(_)) => {}
        other => panic!("corrupt fixture must be Malformed, got {other:?}"),
    }

    // loading via the file path goes through the same parser
    assert!(TraceReplayer::load(&damaged_fixture_path("truncated")).is_err());
    assert!(TraceReplayer::load(&damaged_fixture_path("corrupt")).is_err());
}

/// Over-reading a trace no longer panics: the replayer latches a
/// [`TraceError::Exhausted`] and answers with transport failures so
/// the session winds down through its normal failure handling.
#[test]
fn over_reading_a_trace_latches_an_error() {
    let mut rep = TraceReplayer::load(&fixture_path()).unwrap();
    let recorded: Vec<_> = rep.batches().to_vec();
    for batch in &recorded {
        let live = MeasurementBatch {
            mode: batch.mode,
            requests: live_requests(&batch.requests),
        };
        rep.evaluate(&live);
    }
    assert_eq!(rep.error(), None, "clean replay latches nothing");
    let extra = rep.evaluate(&MeasurementBatch::sequential(vec![MeasurementRequest::Workflow {
        pool_idx: 0,
        config: Config(vec![]),
    }]));
    assert_eq!(extra.len(), 1, "arity contract holds even after the error");
    assert!(!extra[0].is_ok());
    let err = rep.error().expect("exhaustion latched");
    assert_eq!(*err, TraceError::Exhausted { asked: 3, have: 3 });
    assert!(err.to_string().contains("trace exhausted"), "{err}");
}
