//! Trace-format stability tests: a checked-in version-1 fixture must
//! keep replaying on every future build, and a trace written by a
//! *newer* format version must be rejected with a clear error instead
//! of being replayed into garbage results.

use std::path::PathBuf;

use ceal::config::Config;
use ceal::tuner::trace::RecordedRequest;
use ceal::tuner::{
    BatchMode, Evaluator, MeasurementBatch, MeasurementRequest, TraceReplayer, TRACE_VERSION,
};

fn fixture_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/session_trace_v1.jsonl")
}

fn fixture_text() -> String {
    std::fs::read_to_string(fixture_path()).expect("fixture readable")
}

/// Rebuild live requests from a recorded batch (workflow requests
/// match on pool index alone; the carried config is driver payload).
fn live_requests(rec: &[RecordedRequest]) -> Vec<MeasurementRequest> {
    rec.iter()
        .map(|r| match r {
            RecordedRequest::Workflow { pool_idx } => MeasurementRequest::Workflow {
                pool_idx: *pool_idx,
                config: Config(vec![]),
            },
            RecordedRequest::Component { comp, config } => MeasurementRequest::Component {
                comp: *comp,
                config: config.clone(),
            },
        })
        .collect()
}

#[test]
fn checked_in_v1_fixture_replays() {
    assert_eq!(TRACE_VERSION, 1, "bump the fixture alongside the version");
    let mut rep = TraceReplayer::load(&fixture_path()).expect("fixture parses");
    assert_eq!(rep.header.algo, "CEAL");
    assert_eq!(rep.header.workflow, "LV");
    assert_eq!(rep.header.objective, "comp_time");
    assert_eq!(rep.header.m, 4);
    assert_eq!(rep.header.pool_size, 50);
    assert_eq!(rep.header.seed, 51905);
    assert_eq!(rep.header.scorer, "native");
    assert_eq!(rep.header.ceal_params, None);
    assert_eq!(rep.batches().len(), 3);
    assert_eq!(rep.batches()[0].mode, BatchMode::Sequential);
    assert_eq!(rep.batches()[1].mode, BatchMode::FanOut);
    assert_eq!(
        rep.batches()[0].requests[0],
        RecordedRequest::Component {
            comp: 0,
            config: vec![430, 8, 2, 50],
        }
    );

    // serve every batch back and check the recorded values survive the
    // round-trip exactly (integral and fractional alike)
    let recorded: Vec<_> = rep.batches().to_vec();
    for batch in &recorded {
        let live = MeasurementBatch {
            mode: batch.mode,
            requests: live_requests(&batch.requests),
        };
        let results = rep.evaluate(&live);
        let values: Vec<f64> = results.iter().map(|r| r.value).collect();
        assert_eq!(values, batch.values);
    }
    assert_eq!(rep.remaining(), 0);
    assert_eq!(recorded[2].values, [97.0625]);
}

#[test]
fn bumped_version_is_rejected_with_clear_error() {
    let newer = fixture_text().replace("\"version\":1", "\"version\":2");
    assert_ne!(newer, fixture_text(), "replacement must hit");
    let err = TraceReplayer::parse(&newer).unwrap_err();
    assert!(err.contains("version 2"), "error names the trace version: {err}");
    assert!(
        err.contains("version 1") && err.contains("re-record"),
        "error tells the user what to do: {err}"
    );
}

#[test]
fn non_trace_files_are_rejected() {
    assert!(TraceReplayer::parse("").is_err());
    let err = TraceReplayer::parse("{\"workflow\": \"LV\"}").unwrap_err();
    assert!(err.contains("ceal-session-trace"), "{err}");
    // a truncated/corrupt batch line is a parse error, not garbage
    let garbled = format!("{}{}", fixture_text(), "{\"batch\":3,\"mode\":\"seq\"\n");
    assert!(TraceReplayer::parse(&garbled).is_err());
}

#[test]
#[should_panic(expected = "trace exhausted")]
fn over_reading_a_trace_panics() {
    let mut rep = TraceReplayer::load(&fixture_path()).unwrap();
    let recorded: Vec<_> = rep.batches().to_vec();
    for batch in &recorded {
        let live = MeasurementBatch {
            mode: batch.mode,
            requests: live_requests(&batch.requests),
        };
        rep.evaluate(&live);
    }
    rep.evaluate(&MeasurementBatch::sequential(vec![]));
}
