//! The serve subsystem's headline artifact: a soak of hundreds of
//! interleaved sessions — every registered workflow × all seven
//! algorithms × several seeds, round-robined one exchange at a time
//! through one multiplexed [`SessionManager`] — with the daemon
//! "SIGKILLed" (dropped) and restarted on the same serve root twice
//! mid-soak, once with asked-but-untold batches deliberately held
//! across the restart and told to the new daemon *before any re-ask*.
//!
//! Every session's finish payload must be bit-identical to a serial
//! `drive()` of the same (workflow, objective, algorithm, seed) cell:
//! same best index and config, bit-equal collection cost and ground
//! truth, same run/failure/measurement counts.  Interleaving,
//! multiplexing, restarts and out-of-order tells are pure plumbing —
//! they may not perturb a single trajectory.
//!
//! Session count defaults to 210 (≥200 per the subsystem's acceptance
//! bar); `CEAL_SOAK_SESSIONS` overrides it (CI smoke runs 100).

use std::collections::HashMap;

use ceal::config::WorkflowId;
use ceal::coordinator::{session_rng, tuner_for, Algo, PoolCache, ScorerKind};
use ceal::serve::protocol::{
    ask_line, batch_from_json, finish_line, open_line, tell_line, OpenSpec,
};
use ceal::serve::SessionManager;
use ceal::sim::Objective;
use ceal::tuner::{drive, Collector, Evaluator, MeasurementBatch, Problem, TunerOutput};
use ceal::util::json::Json;

const WORKFLOWS: [WorkflowId; 5] = [
    WorkflowId::LV,
    WorkflowId::HS,
    WorkflowId::GP,
    WorkflowId::CH5,
    WorkflowId::DM4,
];
const SEEDS: usize = 6;
const BASE_SEED: u64 = 0x50AC;
const M: usize = 6;
const POOL: usize = 48;
const THREADS: usize = 2;

/// One cell of the soak cross-product.
#[derive(Clone, Copy)]
struct Cell {
    wf: WorkflowId,
    obj: Objective,
    algo: Algo,
    seed: u64,
}

fn cell_for(i: usize) -> Cell {
    let wf = WORKFLOWS[i % WORKFLOWS.len()];
    let algo = Algo::ALL[(i / WORKFLOWS.len()) % Algo::ALL.len()];
    let seed_idx = (i / (WORKFLOWS.len() * Algo::ALL.len())) % SEEDS;
    let obj = if i % 2 == 0 {
        Objective::CompTime
    } else {
        Objective::ExecTime
    };
    Cell {
        wf,
        obj,
        algo,
        seed: BASE_SEED + 1000 * seed_idx as u64,
    }
}

fn spec_for(c: &Cell) -> OpenSpec {
    OpenSpec {
        workflow: c.wf.name().into(),
        objective: c.obj.name().into(),
        algo: c.algo.name().into(),
        m: M,
        pool_size: POOL,
        seed: c.seed,
        scorer: "native".into(),
    }
}

/// The serial reference: identical construction, driven start to
/// finish with no daemon in the loop.
fn serial_reference(c: &Cell) -> (TunerOutput, String, f64) {
    let prob = Problem::new(c.wf, c.obj);
    let pool = PoolCache::global()
        .try_get_or_generate(&prob, POOL, c.seed, THREADS)
        .unwrap_or_else(|e| panic!("pool for {}: {e}", c.wf.name()));
    let scorer = ScorerKind::Native.build();
    let tuner = tuner_for(c.algo, &prob, c.seed, None);
    let mut rng = session_rng(c.seed, c.algo, 0);
    let mut col = Collector::new(&prob, rng.derive_str("collector"));
    let session = tuner.session(&prob, &pool, &scorer, M, &mut rng);
    let out = drive(session, &mut col);
    let best_config = pool.configs[out.best_idx].to_string();
    let best_truth = pool.truth_of(out.best_idx);
    (out, best_config, best_truth)
}

struct Sess<'p> {
    cell: Cell,
    col: Collector<'p>,
    token: String,
    /// An asked batch deliberately held (untold) across a daemon
    /// restart.
    held: Option<(usize, MeasurementBatch)>,
    payload: Option<Json>,
}

fn rpc(mgr: &SessionManager, line: &str) -> Json {
    let resp = mgr.handle_line(line);
    ceal::serve::protocol::parse_response(&resp)
        .unwrap_or_else(|e| panic!("request {line} failed: {e} ({resp})"))
}

fn get_usize(v: &Json, key: &str) -> usize {
    v.get(key)
        .and_then(Json::as_usize)
        .unwrap_or_else(|| panic!("missing '{key}' in {}", v.compact()))
}

fn is_done(v: &Json) -> bool {
    v.get("done").and_then(Json::as_bool).unwrap_or(false)
}

fn finish(mgr: &SessionManager, s: &mut Sess<'_>) {
    s.payload = Some(rpc(mgr, &finish_line(&s.token)));
}

/// One ask/evaluate/tell exchange (or the finish, once done).
fn step(mgr: &SessionManager, s: &mut Sess<'_>) {
    if s.payload.is_some() {
        return; // finished during this round's hold_ask pass
    }
    if let Some((seq, batch)) = s.held.take() {
        // the tell reaches the restarted daemon before any re-ask:
        // only the journal's re-materialized pending batch can answer
        let results = s.col.evaluate(&batch);
        let eval = s.col.checkpoint_state();
        let v = rpc(mgr, &tell_line(&s.token, seq, &results, eval.as_ref()));
        assert!(
            v.get("applied").and_then(Json::as_bool).unwrap_or(false),
            "held tell for {} not applied after restart: {}",
            s.token,
            v.compact()
        );
        if is_done(&v) {
            finish(mgr, s);
        }
        return;
    }
    let a = rpc(mgr, &ask_line(&s.token));
    if is_done(&a) {
        finish(mgr, s);
        return;
    }
    let seq = get_usize(&a, "seq");
    let batch = batch_from_json(a.get("batch").expect("ask batch")).expect("batch decodes");
    let results = s.col.evaluate(&batch);
    let eval = s.col.checkpoint_state();
    let v = rpc(mgr, &tell_line(&s.token, seq, &results, eval.as_ref()));
    if is_done(&v) {
        finish(mgr, s);
    }
}

/// Ask and hold the batch untold (simulating a client whose tell is
/// in flight when the daemon dies).  Sessions that turn out to be
/// complete finish instead.
fn hold_ask(mgr: &SessionManager, s: &mut Sess<'_>) {
    let a = rpc(mgr, &ask_line(&s.token));
    if is_done(&a) {
        finish(mgr, s);
        return;
    }
    let seq = get_usize(&a, "seq");
    let batch = batch_from_json(a.get("batch").expect("ask batch")).expect("batch decodes");
    s.held = Some((seq, batch));
}

#[test]
fn soak_interleaved_sessions_bit_identical_across_daemon_restarts() {
    let count: usize = std::env::var("CEAL_SOAK_SESSIONS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(210);
    let root = std::env::temp_dir().join(format!("ceal-serve-soak-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);

    let cells: Vec<Cell> = (0..count).map(cell_for).collect();
    let probs: Vec<Problem> = cells.iter().map(|c| Problem::new(c.wf, c.obj)).collect();

    let mut mgr = SessionManager::new(&root, THREADS, None).unwrap();
    let mut sessions: Vec<Sess<'_>> = cells
        .iter()
        .zip(&probs)
        .map(|(c, prob)| {
            let v = rpc(&mgr, &open_line(&spec_for(c)));
            let token = v
                .get("token")
                .and_then(Json::as_str)
                .expect("open token")
                .to_string();
            let mut rng = session_rng(c.seed, c.algo, 0);
            Sess {
                cell: *c,
                col: Collector::new(prob, rng.derive_str("collector")),
                token,
                held: None,
                payload: None,
            }
        })
        .collect();

    let mut round = 0usize;
    loop {
        let unfinished: Vec<usize> = sessions
            .iter()
            .enumerate()
            .filter(|(_, s)| s.payload.is_none())
            .map(|(i, _)| i)
            .collect();
        if unfinished.is_empty() {
            break;
        }
        match round {
            // kill-round: half the tenants have an asked-but-untold
            // batch in flight when the daemon dies; their tells hit
            // the restarted daemon first
            2 => {
                for &i in &unfinished {
                    if i % 2 == 0 {
                        hold_ask(&mgr, &mut sessions[i]);
                    }
                }
                mgr = SessionManager::new(&root, THREADS, None).unwrap();
            }
            // plain SIGKILL/restart between clean exchanges
            5 => {
                mgr = SessionManager::new(&root, THREADS, None).unwrap();
            }
            _ => {}
        }
        for &i in &unfinished {
            step(&mgr, &mut sessions[i]);
        }
        round += 1;
        assert!(round < 10_000, "soak failed to converge");
    }

    // every trajectory bit-identical to its serial reference
    type CellKey = (&'static str, &'static str, &'static str, u64);
    let mut refs: HashMap<CellKey, (TunerOutput, String, f64)> = HashMap::new();
    for s in &sessions {
        let c = &s.cell;
        let key = (c.wf.name(), c.obj.name(), c.algo.name(), c.seed);
        let (reference, best_config, best_truth) =
            refs.entry(key).or_insert_with(|| serial_reference(c));
        let p = s.payload.as_ref().expect("session finished");
        let label = format!("{}/{}/{}/{:x}", c.wf.name(), c.obj.name(), c.algo.name(), c.seed);
        assert_eq!(
            p.get("best_idx").and_then(Json::as_usize),
            Some(reference.best_idx),
            "{label}: best_idx diverges"
        );
        assert_eq!(
            p.get("best_config").and_then(Json::as_str),
            Some(best_config.as_str()),
            "{label}: best_config diverges"
        );
        let truth = p
            .get("best_truth")
            .and_then(Json::as_f64)
            .unwrap_or_else(|| panic!("{label}: payload best_truth missing"));
        assert_eq!(
            truth.to_bits(),
            best_truth.to_bits(),
            "{label}: best_truth diverges"
        );
        let cost = p
            .get("collection_cost")
            .and_then(Json::as_f64)
            .unwrap_or_else(|| panic!("{label}: payload collection_cost missing"));
        assert_eq!(
            cost.to_bits(),
            reference.collection_cost.to_bits(),
            "{label}: collection cost diverges ({cost} vs {})",
            reference.collection_cost
        );
        assert_eq!(
            p.get("workflow_runs").and_then(Json::as_usize),
            Some(reference.workflow_runs),
            "{label}: workflow_runs diverges"
        );
        assert_eq!(
            p.get("failed_runs").and_then(Json::as_usize),
            Some(reference.failed_runs),
            "{label}: failed_runs diverges"
        );
        assert_eq!(
            p.get("measured").and_then(Json::as_usize),
            Some(reference.measured.len()),
            "{label}: measured count diverges"
        );
    }
    let _ = std::fs::remove_dir_all(&root);
}
