//! Property tests over the coordinator's invariants (budget accounting,
//! pool/selection state, metric bounds, simulator monotonicities) using
//! the in-repo property harness (`ceal::util::prop`).

use std::collections::HashSet;

use ceal::config::{Config, WorkflowId, F_MAX};
use ceal::gbt::{train, train_exact, train_log, Ensemble, GbtParams};
use ceal::metrics::{mdape, recall_score};
use ceal::sim::{Objective, SimWorkspace};
use ceal::surrogate::Scorer;
use ceal::tuner::{
    ActiveLearning, Alph, Ceal, CealParams, Geist, Pool, Problem, RandomSampling, Tuner,
};
use ceal::util::prop::{assert_prop, check};
use ceal::util::rng::Pcg32;

/// A random problem over *every registered* workflow — the paper trio
/// plus the synthetic scenario families (CH5 / DM4), so all tuner
/// invariants hold for registry-added scenarios too.
fn any_problem(rng: &mut Pcg32) -> Problem {
    let ids = ceal::sim::WorkflowRegistry::global().ids();
    let wf = *rng.choose(&ids);
    let obj = *rng.choose(&Objective::ALL);
    Problem::new(wf, obj)
}

#[test]
fn tuners_respect_budget_and_uniqueness() {
    let tuners: Vec<(&str, Box<dyn Tuner>)> = vec![
        ("RS", Box::new(RandomSampling)),
        ("AL", Box::new(ActiveLearning::default())),
        ("GEIST", Box::new(Geist::default())),
        ("CEAL", Box::new(Ceal::new(CealParams::no_hist()))),
        ("ALpH", Box::new(Alph::new(CealParams::no_hist()))),
    ];
    check("budget/uniqueness/valid-output", 20, |rng| {
        let prob = any_problem(rng);
        let pool = Pool::generate(&prob, 80 + rng.gen_range(80) as usize, rng.next_u64());
        let m = 10 + rng.gen_range(40) as usize;
        let (name, tuner) = &tuners[rng.gen_range(tuners.len() as u64) as usize];
        let mut trng = rng.derive(1);
        let out = tuner.run(&prob, &pool, &Scorer::Native, m, &mut trng);
        assert_prop(
            out.workflow_runs <= m,
            format!("{name}: {} workflow runs exceed budget {m}", out.workflow_runs),
        )?;
        assert_prop(
            out.measured.len() == out.workflow_runs,
            format!("{name}: measured len != workflow runs"),
        )?;
        let distinct: HashSet<usize> = out.measured.iter().map(|&(i, _)| i).collect();
        assert_prop(
            distinct.len() == out.measured.len(),
            format!("{name}: duplicate pool indices measured"),
        )?;
        assert_prop(out.best_idx < pool.len(), format!("{name}: best_idx out of range"))?;
        assert_prop(
            out.collection_cost > 0.0 && out.collection_cost.is_finite(),
            format!("{name}: bad collection cost {}", out.collection_cost),
        )?;
        assert_prop(
            out.measured.iter().all(|&(_, y)| y > 0.0 && y.is_finite()),
            format!("{name}: non-positive measurement"),
        )
    });
}

#[test]
fn pool_invariants() {
    check("pool feasible/dedup/deterministic", 12, |rng| {
        let prob = any_problem(rng);
        let seed = rng.next_u64();
        let n = 40 + rng.gen_range(60) as usize;
        let a = Pool::generate(&prob, n, seed);
        let b = Pool::generate(&prob, n, seed);
        assert_prop(a.configs == b.configs, "pool not deterministic")?;
        let set: HashSet<&Config> = a.configs.iter().collect();
        assert_prop(set.len() == n, "pool contains duplicates")?;
        for c in &a.configs {
            assert_prop(prob.sim.feasible(c), format!("infeasible pool config {c}"))?;
            assert_prop(
                prob.sim.spec.validate(c).is_ok(),
                format!("invalid pool config {c}"),
            )?;
        }
        let best = a.best_value();
        assert_prop(a.truth().iter().all(|&v| v >= best), "best_value not minimal")
    });
}

/// Reusing one simulator workspace across runs (the collector's hot
/// path) must be observationally identical to a fresh workspace per
/// call, for noisy and noise-free runs alike.
#[test]
fn workspace_reuse_is_invisible() {
    check("reused workspace == fresh workspace", 12, |rng| {
        let prob = any_problem(rng);
        let feasible = |c: &Config| prob.sim.feasible(c);
        let mut cfg_rng = rng.derive(3);
        let cfgs: Vec<Config> = (0..5)
            .map(|_| prob.sim.spec.sample_feasible(&mut cfg_rng, &feasible, 100_000))
            .collect();
        let mut ws = SimWorkspace::new();
        let mut r_reused = rng.derive(4);
        let mut r_fresh = r_reused.clone();
        for cfg in &cfgs {
            let reused = prob.sim.run_with(cfg, &mut r_reused, &mut ws);
            let fresh = prob.sim.run_with(cfg, &mut r_fresh, &mut SimWorkspace::new());
            assert_prop(
                reused == fresh,
                format!("noisy run diverged: {reused:?} vs {fresh:?}"),
            )?;
            let e_reused = prob.sim.expected_with(cfg, &mut ws);
            let e_fresh = prob.sim.expected(cfg);
            assert_prop(
                e_reused == e_fresh,
                format!("expected run diverged: {e_reused:?} vs {e_fresh:?}"),
            )?;
        }
        Ok(())
    });
}

/// Pool ground truth is measured in parallel on cache misses; the
/// result must be bit-identical for every worker count.
#[test]
fn pool_parallel_truth_matches_serial() {
    check("generate_par == generate", 6, |rng| {
        let prob = any_problem(rng);
        let seed = rng.next_u64();
        let n = 30 + rng.gen_range(50) as usize;
        let serial = Pool::generate(&prob, n, seed);
        let threads = 2 + rng.gen_range(6) as usize;
        let par = Pool::generate_par(&prob, n, seed, threads);
        assert_prop(serial.configs == par.configs, "configs diverged")?;
        assert_prop(serial.truth() == par.truth(), "truth diverged")?;
        assert_prop(serial.best_idx() == par.best_idx(), "best_idx diverged")
    });
}

#[test]
fn recall_and_mdape_bounds() {
    check("metric bounds", 200, |rng| {
        let n = 3 + rng.gen_range(40) as usize;
        let actual: Vec<f64> = (0..n).map(|_| 1.0 + rng.f64() * 100.0).collect();
        let pred: Vec<f64> = (0..n).map(|_| 1.0 + rng.f64() * 100.0).collect();
        let k = 1 + rng.gen_range(n as u64) as usize;
        let r = recall_score(k, &pred, &actual);
        assert_prop((0.0..=1.0).contains(&r), format!("recall {r} out of range"))?;
        let perfect = recall_score(k, &actual, &actual);
        assert_prop((perfect - 1.0).abs() < 1e-12, "self-recall must be 1")?;
        let e = mdape(&actual, &pred);
        assert_prop(e >= 0.0 && e.is_finite(), format!("mdape {e}"))?;
        assert_prop(mdape(&actual, &actual) == 0.0, "self-mdape must be 0")
    });
}

#[test]
fn simulator_noise_is_bounded_and_seeded() {
    check("simulator noise", 15, |rng| {
        let prob = any_problem(rng);
        let cfg = {
            let feasible = |c: &Config| prob.sim.feasible(c);
            let mut srng = rng.derive(2);
            prob.sim.spec.sample_feasible(&mut srng, &feasible, 100_000)
        };
        let expected = prob.objective.value(&prob.sim.expected(&cfg));
        assert_prop(expected > 0.0, "expected value must be positive")?;
        // same seed -> same noisy measurement
        let mut r1 = Pcg32::new(99, 1);
        let mut r2 = Pcg32::new(99, 1);
        let a = prob.objective.value(&prob.sim.run(&cfg, &mut r1));
        let b = prob.objective.value(&prob.sim.run(&cfg, &mut r2));
        assert_prop(a == b, "noisy run not reproducible under same seed")?;
        // noise is multiplicative and small
        assert_prop(
            (a / expected - 1.0).abs() < 0.5,
            format!("noise too large: {a} vs {expected}"),
        )
    });
}

#[test]
fn flattened_ensembles_match_native_predictor() {
    check("flatten == native", 30, |rng| {
        let n = 20 + rng.gen_range(100) as usize;
        let nf = 1 + rng.gen_range(7) as usize;
        let xs: Vec<[f32; ceal::config::F_MAX]> = (0..n)
            .map(|_| {
                let mut x = [0f32; ceal::config::F_MAX];
                for v in x.iter_mut().take(nf) {
                    *v = rng.f32();
                }
                x
            })
            .collect();
        let y: Vec<f64> = xs.iter().map(|x| 1.0 + 30.0 * x[0] as f64).collect();
        let params = GbtParams {
            n_trees: 1 + rng.gen_range(40) as usize,
            depth: 1 + rng.gen_range(5) as usize,
            ..GbtParams::small_data()
        };
        let ens = train_log(&xs, &y, nf, &params);
        let flat = ens.flatten();
        for x in xs.iter().take(20) {
            let a = ens.predict(x);
            let b = flat.predict(x);
            assert_prop(
                (a - b).abs() < 1e-4 * (1.0 + a.abs()),
                format!("flatten mismatch {a} vs {b}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn objective_combination_matches_artifact_semantics() {
    check("combine max/sum", 100, |rng| {
        let j = 1 + rng.gen_range(4) as usize;
        let parts: Vec<f64> = (0..j).map(|_| rng.f64() * 50.0 + 0.1).collect();
        let mx = Objective::ExecTime.combine(&parts);
        let sm = Objective::CompTime.combine(&parts);
        let want_max = parts.iter().cloned().fold(f64::MIN, f64::max);
        let want_sum: f64 = parts.iter().sum();
        assert_prop((mx - want_max).abs() < 1e-12, "max mismatch")?;
        assert_prop((sm - want_sum).abs() < 1e-12, "sum mismatch")?;
        // mode scalars match the artifact convention
        assert_prop(Objective::ExecTime.mode() == 1.0, "exec mode")?;
        assert_prop(Objective::CompTime.mode() == 0.0, "comp mode")
    });
}

fn random_rows(rng: &mut Pcg32, n: usize, nf: usize) -> Vec<[f32; F_MAX]> {
    (0..n)
        .map(|_| {
            let mut x = [0f32; F_MAX];
            for v in x.iter_mut().take(nf) {
                *v = rng.f32();
            }
            x
        })
        .collect()
}

/// Differential test for the histogram training engine: same candidate
/// thresholds, gain formula and tie-breaks as `train_exact`, so holdout
/// RMSE must agree within a small fraction of the target spread (the
/// engines can only diverge through last-bit f64 rounding of gradient
/// sums flipping a near-tied split).
#[test]
fn histogram_trainer_matches_exact_holdout_rmse() {
    check("hist-vs-exact holdout rmse", 8, |rng| {
        let n = 60 + rng.gen_range(240) as usize;
        let nf = 2 + rng.gen_range(6) as usize; // 2..=7 real features
        let w: Vec<f64> = (0..nf).map(|_| rng.uniform(-10.0, 10.0)).collect();
        let q: Vec<f64> = (0..nf).map(|_| rng.uniform(0.0, 5.0)).collect();
        let truth = |x: &[f32; F_MAX]| {
            let mut v = 30.0;
            for f in 0..nf {
                v += w[f] * x[f] as f64 + q[f] * ((x[f] as f64) - 0.5).powi(2);
            }
            v
        };
        let xs = random_rows(rng, n, nf);
        let y: Vec<f64> = xs.iter().map(&truth).collect();
        let tx = random_rows(rng, 150, nf);
        let ty: Vec<f64> = tx.iter().map(&truth).collect();
        let params = GbtParams {
            n_trees: 8 + rng.gen_range(40) as usize,
            depth: 2 + rng.gen_range(4) as usize,
            ..GbtParams::default()
        };
        let hist = train(&xs, &y, nf, &params);
        let exact = train_exact(&xs, &y, nf, &params);
        let rmse = |m: &Ensemble| {
            let se: f64 = tx
                .iter()
                .zip(&ty)
                .map(|(x, &t)| {
                    let d = m.predict(x) as f64 - t;
                    d * d
                })
                .sum();
            (se / ty.len() as f64).sqrt()
        };
        let (rh, re) = (rmse(&hist), rmse(&exact));
        let spread = ceal::util::stats::std_dev(&ty);
        assert_prop(
            (rh - re).abs() <= 0.05 * spread + 1e-9,
            format!("n={n} nf={nf}: hist rmse {rh} vs exact rmse {re} (spread {spread})"),
        )
    });
}

/// The blocked batched predictors must equal the row-at-a-time
/// predictors exactly, on arbitrary (not just trained) ensembles and
/// across block-boundary batch sizes.
#[test]
fn batched_prediction_equals_rowwise() {
    check("predict_batch == predict", 25, |rng| {
        let trees = 1 + rng.gen_range(64) as usize; // 1..=TREES_MAX
        let depth = 1 + rng.gen_range(6) as usize; // 1..=DEPTH_MAX
        let nf = 1 + rng.gen_range(8) as usize;
        let leaves_w = 1usize << depth;
        let ens = Ensemble {
            n_features: nf,
            depth,
            feat: (0..trees * depth)
                .map(|_| rng.gen_range(nf as u64) as u32)
                .collect(),
            thr: (0..trees * depth).map(|_| rng.f32()).collect(),
            leaves: (0..trees * leaves_w)
                .map(|_| rng.normal() as f32)
                .collect(),
            bias: rng.normal() as f32,
        };
        let n = 1 + rng.gen_range(300) as usize;
        let xs = random_rows(rng, n, F_MAX);
        let batch = ens.predict_batch(&xs);
        let flat = ens.flatten();
        let flat_batch = flat.predict_batch(&xs);
        assert_prop(
            batch.len() == n && flat_batch.len() == n,
            "batched output length mismatch",
        )?;
        for (i, x) in xs.iter().enumerate() {
            let row = ens.predict(x);
            assert_prop(
                batch[i] == row,
                format!("row {i}/{n}: batch {} vs rowwise {row}", batch[i]),
            )?;
            let flat_row = flat.predict(x);
            assert_prop(
                flat_batch[i] == flat_row,
                format!("row {i}/{n}: flat batch {} vs rowwise {flat_row}", flat_batch[i]),
            )?;
        }
        Ok(())
    });
}

/// Failure injection: tuners must survive degenerate setups.
#[test]
fn degenerate_setups() {
    // budget of 1-3 runs on a tiny pool must not panic
    let prob = Problem::new(WorkflowId::HS, Objective::ExecTime);
    let pool = Pool::generate(&prob, 20, 5);
    for m in [1usize, 2, 3] {
        let mut rng = Pcg32::new(m as u64, 0);
        let out = Ceal::new(CealParams::no_hist()).run(&prob, &pool, &Scorer::Native, m, &mut rng);
        assert!(out.workflow_runs >= 1);
        assert!(out.best_idx < pool.len());
    }
    // budget exceeding the pool saturates instead of panicking
    let mut rng = Pcg32::new(9, 0);
    let out = RandomSampling.run(&prob, &pool, &Scorer::Native, 10_000, &mut rng);
    assert!(out.workflow_runs <= pool.len());
}
