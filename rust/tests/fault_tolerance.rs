//! Fault-tolerance properties of the measurement layer:
//!
//! * every registered algorithm finishes — no panic, no hang — on
//!   every built-in workflow under a harsh randomized fault schedule
//!   (20% failures, 5% timeouts, swept schedule seeds);
//! * an identical (fault plan, schedule seed) reproduces the whole
//!   trajectory bit-exactly;
//! * a zero-probability fault injector is an exact identity: wrapping
//!   the collector must not perturb a single bit of today's fault-free
//!   behaviour (the session_equivalence pins stay green by the same
//!   argument);
//! * the cost-budgeted session (a float budget, not a run count — so
//!   not part of the campaign roster) terminates under the same
//!   schedules.

use ceal::config::WorkflowId;
use ceal::coordinator::{session_rng, tuner_for, Algo};
use ceal::sim::Objective;
use ceal::surrogate::Scorer;
use ceal::tuner::{
    drive, BudgetedCeal, BudgetedCealParams, Collector, FailurePolicy, FaultInjector, FaultPlan,
    Pool, Problem, TunerOutput,
};
use ceal::util::rng::Pcg32;

const WORKFLOWS: [WorkflowId; 5] = [
    WorkflowId::LV,
    WorkflowId::HS,
    WorkflowId::GP,
    WorkflowId::CH5,
    WorkflowId::DM4,
];

const POOL: usize = 60;
const M: usize = 12;

/// Drive one session against a fault-injected collector, exactly as a
/// faulted campaign repetition would.
fn run_faulted(
    algo: Algo,
    prob: &Problem,
    pool: &Pool,
    plan: FaultPlan,
    fault_seed: u64,
) -> TunerOutput {
    let tuner = tuner_for(algo, prob, 0xCEA1, None);
    let mut rng = session_rng(0xCEA1, algo, 0);
    let mut col = Collector::new(prob, rng.derive_str("collector"));
    let mut session = tuner.session(prob, pool, &Scorer::Native, M, &mut rng);
    session.set_failure_policy(FailurePolicy::fault_tolerant());
    let mut injector = FaultInjector::new(&mut col, plan, fault_seed);
    drive(session, &mut injector)
}

fn run_clean(algo: Algo, prob: &Problem, pool: &Pool) -> TunerOutput {
    let tuner = tuner_for(algo, prob, 0xCEA1, None);
    let mut rng = session_rng(0xCEA1, algo, 0);
    let mut col = Collector::new(prob, rng.derive_str("collector"));
    drive(
        tuner.session(prob, pool, &Scorer::Native, M, &mut rng),
        &mut col,
    )
}

#[test]
fn every_algorithm_finishes_on_every_workflow_under_faults() {
    let plan = FaultPlan::transient(0.2, 0.05);
    let mut total_failed = 0usize;
    for wf in WORKFLOWS {
        let prob = Problem::new(wf, Objective::CompTime);
        let pool = Pool::generate(&prob, POOL, 0xCEA1);
        for algo in Algo::ALL {
            for fault_seed in [11u64, 97] {
                let out = run_faulted(algo, &prob, &pool, plan, fault_seed);
                assert!(
                    out.best_idx < pool.len(),
                    "{algo} on {wf} (fault seed {fault_seed}): bad best_idx"
                );
                assert!(
                    out.collection_cost.is_finite() && out.collection_cost >= 0.0,
                    "{algo} on {wf}: non-finite cost"
                );
                total_failed += out.failed_runs;
            }
        }
    }
    assert!(
        total_failed > 0,
        "a 20%/5% schedule over {} sessions must hit some attempts",
        WORKFLOWS.len() * Algo::ALL.len() * 2
    );
}

#[test]
fn identical_fault_spec_reproduces_the_run_bit_exactly() {
    let plan = FaultPlan::transient(0.2, 0.05);
    let prob = Problem::new(WorkflowId::LV, Objective::CompTime);
    let pool = Pool::generate(&prob, POOL, 0xCEA1);
    let mut any_schedule_diff = false;
    for algo in [Algo::Rs, Algo::Ceal, Algo::Alph] {
        let a = run_faulted(algo, &prob, &pool, plan, 7);
        let b = run_faulted(algo, &prob, &pool, plan, 7);
        assert_eq!(a.best_idx, b.best_idx, "{algo}");
        assert_eq!(
            a.collection_cost.to_bits(),
            b.collection_cost.to_bits(),
            "{algo}: cost must be bit-identical"
        );
        assert_eq!(a.failed_runs, b.failed_runs, "{algo}");
        assert_eq!(a.measured, b.measured, "{algo}: trajectory must match");
        // a different schedule seed must eventually produce a
        // different run, or the pass above is vacuous
        let c = run_faulted(algo, &prob, &pool, plan, 8);
        any_schedule_diff |= c.failed_runs != a.failed_runs || c.measured != a.measured;
    }
    assert!(
        any_schedule_diff,
        "schedule seed never changed any run — fate derivation is ignoring it"
    );
}

/// p_fail = 0 end to end: wrapping the collector in a no-op injector
/// (and leaving the default policy in place) must reproduce today's
/// fault-free runs bit for bit.
#[test]
fn zero_probability_injector_is_an_exact_identity() {
    let prob = Problem::new(WorkflowId::HS, Objective::CompTime);
    let pool = Pool::generate(&prob, POOL, 0xCEA1);
    for algo in Algo::ALL {
        let clean = run_clean(algo, &prob, &pool);
        let tuner = tuner_for(algo, &prob, 0xCEA1, None);
        let mut rng = session_rng(0xCEA1, algo, 0);
        let mut col = Collector::new(&prob, rng.derive_str("collector"));
        let session = tuner.session(&prob, &pool, &Scorer::Native, M, &mut rng);
        let mut injector = FaultInjector::new(&mut col, FaultPlan::none(), 7);
        let wrapped = drive(session, &mut injector);
        assert_eq!(clean.best_idx, wrapped.best_idx, "{algo}");
        assert_eq!(
            clean.collection_cost.to_bits(),
            wrapped.collection_cost.to_bits(),
            "{algo}: zero-fault cost must be bit-identical"
        );
        assert_eq!(clean.measured, wrapped.measured, "{algo}");
        assert_eq!(wrapped.failed_runs, 0, "{algo}");
    }
}

#[test]
fn budgeted_session_terminates_under_faults() {
    let prob = Problem::new(WorkflowId::LV, Objective::CompTime);
    let pool = Pool::generate(&prob, POOL, 0xCEA1);
    let tuner = BudgetedCeal::new(BudgetedCealParams::default());
    // a budget in objective units, roughly a dozen median runs
    let budget = pool.truth().iter().sum::<f64>() / pool.len() as f64 * 12.0;
    for fault_seed in [11u64, 97] {
        let mut rng = Pcg32::new(0xB4D6, 0);
        let mut col = Collector::new(&prob, rng.derive_str("collector"));
        let mut session =
            tuner.session_with_cost_budget(&prob, &pool, &Scorer::Native, budget, &mut rng);
        session.set_failure_policy(FailurePolicy::fault_tolerant());
        let mut injector =
            FaultInjector::new(&mut col, FaultPlan::transient(0.2, 0.05), fault_seed);
        let out = drive(session, &mut injector);
        assert!(out.best_idx < pool.len());
        assert!(out.collection_cost.is_finite());
    }
}
