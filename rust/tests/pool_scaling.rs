//! Lazy-pool equivalence pins for the million-config pool redesign.
//!
//! A lazy pool materializes only the feature/prediction side of the
//! candidate set; ground truth is simulated on demand and memoized.
//! Tuner sessions never read pool truth on their happy path (they
//! measure through the `Collector`), so running any algorithm on a
//! lazy pool must be *bit-identical* to running it on the eagerly
//! measured pool built from the same seed — same candidate stream,
//! same measured trajectory, same searcher pick, same accounting.
//! These tests pin that for all seven registered session tuners at
//! the paper's pool size, and check that the on-demand truth cache
//! stays proportional to what was actually asked for.

use std::sync::Arc;

use ceal::config::WorkflowId;
use ceal::coordinator::historical_samples;
use ceal::sim::Objective;
use ceal::surrogate::Scorer;
use ceal::tuner::{
    ActiveLearning, Alph, Ceal, CealParams, Geist, Pool, Problem, RandomSampling, Tuner,
    TunerOutput, LAZY_POOL_MIN, POOL_SIZE,
};
use ceal::util::rng::Pcg32;

/// Bit-identity on everything a session run reports: the measured
/// trajectory (indices and values), the searcher pick, cost
/// accounting, and the trained model.
fn assert_outputs_identical(label: &str, eager: &TunerOutput, lazy: &TunerOutput) {
    assert_eq!(
        eager.measured, lazy.measured,
        "{label}: measured trajectories diverge"
    );
    assert_eq!(eager.best_idx, lazy.best_idx, "{label}: searcher picks diverge");
    assert_eq!(
        eager.collection_cost.to_bits(),
        lazy.collection_cost.to_bits(),
        "{label}: collection cost diverges"
    );
    assert_eq!(eager.workflow_runs, lazy.workflow_runs, "{label}: run counts diverge");
    assert_eq!(eager.model, lazy.model, "{label}: final models diverge");
}

/// The seven registered session algorithms, in roster order.
fn roster(prob: &Problem, seed: u64) -> Vec<(&'static str, Box<dyn Tuner>)> {
    let hist = Arc::new(historical_samples(prob, 60, seed ^ 0x415));
    vec![
        ("RS", Box::new(RandomSampling) as Box<dyn Tuner>),
        ("AL", Box::new(ActiveLearning::default())),
        ("GEIST", Box::new(Geist::default())),
        ("CEAL", Box::new(Ceal::new(CealParams::no_hist()))),
        (
            "CEAL+hist",
            Box::new(Ceal::with_historical(
                CealParams::with_hist(),
                Arc::clone(&hist),
            )),
        ),
        ("ALpH", Box::new(Alph::new(CealParams::no_hist()))),
        (
            "ALpH+hist",
            Box::new(Alph::with_historical(CealParams::with_hist(), hist)),
        ),
    ]
}

/// Every algorithm, same RNG streams, eager vs lazy pool at the
/// paper's pool size: bit-identical outputs, and the lazy truth cache
/// holds only the cells this test itself asked for afterwards.
#[test]
fn lazy_pool_trajectories_match_eager_for_every_algorithm() {
    let prob = Problem::new(WorkflowId::LV, Objective::CompTime);
    let seed = 0x1A2B;
    let eager = Pool::generate(&prob, POOL_SIZE, seed);
    let lazy = Pool::generate_lazy(&prob, POOL_SIZE, seed);
    assert!(!eager.is_lazy());
    assert!(lazy.is_lazy());
    assert!(lazy.truth_eager().is_none(), "lazy pool must not hold a truth vector");
    // identical candidate stream: the truth side is the only difference
    assert_eq!(eager.configs, lazy.configs, "candidate streams diverge");
    assert_eq!(
        eager.feats.workflow, lazy.feats.workflow,
        "workflow features diverge"
    );

    let scorer = Scorer::Native;
    let m = 20;
    let tuners = roster(&prob, seed);
    let n_tuners = tuners.len();
    for (stream, (name, tuner)) in tuners.into_iter().enumerate() {
        let mut r_eager = Pcg32::new(0xE4A1, stream as u64);
        let mut r_lazy = Pcg32::new(0xE4A1, stream as u64);
        let on_eager = tuner.run(&prob, &eager, &scorer, m, &mut r_eager);
        let on_lazy = tuner.run(&prob, &lazy, &scorer, m, &mut r_lazy);
        assert_outputs_identical(name, &on_eager, &on_lazy);
        // on-demand truth agrees bitwise with the eager measurement
        assert_eq!(
            eager.truth_of(on_eager.best_idx).to_bits(),
            lazy.truth_of(on_lazy.best_idx).to_bits(),
            "{name}: lazy ground truth diverges from eager"
        );
    }
    // nothing beyond the truth_of() probes above was ever simulated:
    // the sessions themselves never touched pool truth
    assert!(
        lazy.lazy_truth_count() <= n_tuners,
        "lazy cache grew past the {} explicit probes: {}",
        n_tuners,
        lazy.lazy_truth_count()
    );
}

/// End-to-end smoke above the auto-lazy threshold: a pool too large to
/// measure eagerly in a test still tunes, the searcher crosses the
/// quantized scoring path (pool len > QUANTIZE_MIN_ROWS), and memory
/// stays on the feature/prediction side — no truth vector, and only
/// the probed cells in the cache.
#[test]
fn large_lazy_pool_tunes_without_materializing_truth() {
    let prob = Problem::new(WorkflowId::LV, Objective::CompTime);
    let pool = Pool::generate_lazy(&prob, LAZY_POOL_MIN, 0xB16);
    assert_eq!(pool.len(), LAZY_POOL_MIN);
    assert!(pool.is_lazy());

    let mut rng = Pcg32::new(0xB16, 1);
    let out = Ceal::new(CealParams::no_hist()).run(&prob, &pool, &Scorer::Native, 12, &mut rng);
    assert!(out.best_idx < pool.len());
    assert!(out.workflow_runs > 0 && out.workflow_runs <= 12);
    assert!(out.measured.len() <= 12);

    // the run itself left the truth side untouched; one probe fills
    // exactly one cell
    assert_eq!(pool.lazy_truth_count(), 0, "tuning must not force ground truth");
    let probed = pool.truth_of(out.best_idx);
    assert!(probed.is_finite() && probed > 0.0);
    assert_eq!(pool.lazy_truth_count(), 1);

    // memory model: the lazy pool's footprint is dominated by configs
    // and features, far below what an eager truth vector would add at
    // this size (accounting sanity, not an allocator measurement)
    let bytes = pool.approx_bytes();
    assert!(bytes > 0);

    // Amortization invariant: the whole run coded the pool's workflow
    // features exactly once — every per-refit selection pass re-ranked
    // into that resident grid instead of re-coding O(pool·F).  The
    // per-cache counters (not the process-global ones) keep this
    // assertion immune to tests running in parallel.
    assert_eq!(
        pool.feats.workflow_codes.builds(),
        1,
        "a CEAL run must build the workflow pool codes exactly once"
    );
    assert!(
        pool.feats.workflow_codes.approx_bytes() > 0,
        "built codes must be resident"
    );
    for cc in &pool.feats.component_codes {
        assert!(
            cc.builds() <= 1,
            "component views must never code more than once"
        );
    }

    // Exactness: the model this run actually produced, re-ranked into
    // the resident codes, scores the pool bit-identically to a
    // from-scratch quantized build over the raw features.
    use ceal::gbt::QuantizedEnsemble;
    let codes = pool.feats.workflow_codes.get_or_build(&pool.feats.workflow);
    let reranked = QuantizedEnsemble::rerank(&out.model, &codes);
    let rebuilt = QuantizedEnsemble::build(&out.model, &pool.feats.workflow);
    let a = reranked.predict_all();
    let b = rebuilt.predict_all();
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "re-ranked vs rebuilt prediction diverges at row {i}"
        );
    }
}
