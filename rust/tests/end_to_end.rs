//! End-to-end integration: small-scale campaigns across the whole grid
//! must run, produce sane aggregates, and reproduce the paper's
//! *qualitative* orderings (CEAL ≥ RS everywhere; history helps CEAL;
//! CEAL with history beats ALpH with history).

use ceal::config::WorkflowId;
use ceal::coordinator::{run_campaign, Algo, Campaign};
use ceal::exper::{self, ExpCtx};
use ceal::sim::Objective;

fn quick(wf: WorkflowId, obj: Objective, m: usize, reps: usize) -> Campaign {
    Campaign::new(wf, obj, m)
        .with_reps(reps)
        .with_pool_size(300)
        .with_threads(2)
}

#[test]
fn full_grid_runs_and_aggregates() {
    for wf in WorkflowId::ALL {
        for obj in Objective::ALL {
            let agg = run_campaign(Algo::Ceal, &quick(wf, obj, 20, 3));
            assert_eq!(agg.reps.len(), 3, "{wf}/{obj}");
            assert!(agg.mean_norm_best() >= 1.0, "{wf}/{obj}");
            assert!(agg.mean_norm_best() < 50.0, "{wf}/{obj}: absurd tuning result");
            assert!(agg.pool_best > 0.0 && agg.expert_value > 0.0);
            for r in &agg.reps {
                assert_eq!(r.recalls.len(), 10);
                assert!(r.mdape_all.is_finite() && r.mdape_top2.is_finite());
            }
        }
    }
}

/// The registry-added synthetic scenarios (CH5 chain, DM4 diamond)
/// flow untouched through pool generation, campaigns and metrics —
/// and CEAL's component-model advantage carries over to them.
#[test]
fn new_scenarios_run_full_campaigns_and_ceal_beats_rs() {
    let mut ceal_sum = 0.0;
    let mut rs_sum = 0.0;
    for wf in [WorkflowId::CH5, WorkflowId::DM4] {
        for obj in Objective::ALL {
            let ceal = run_campaign(Algo::Ceal, &quick(wf, obj, 25, 6));
            let rs = run_campaign(Algo::Rs, &quick(wf, obj, 25, 6));
            assert_eq!(ceal.reps.len(), 6, "{wf}/{obj}");
            assert!(ceal.mean_norm_best() >= 1.0, "{wf}/{obj}");
            assert!(ceal.mean_norm_best() < 50.0, "{wf}/{obj}: absurd tuning result");
            assert!(ceal.pool_best > 0.0 && ceal.expert_value > 0.0, "{wf}/{obj}");
            ceal_sum += ceal.mean_norm_best();
            rs_sum += rs.mean_norm_best();
        }
    }
    assert!(
        ceal_sum < rs_sum,
        "CEAL mean normalized {ceal_sum} should beat RS {rs_sum} on CH5/DM4"
    );
}

#[test]
fn ceal_beats_rs_on_average() {
    // paper Fig. 5's coarsest claim, at reduced scale: averaged over the
    // grid, CEAL's tuned configs beat RS's.
    let mut ceal_sum = 0.0;
    let mut rs_sum = 0.0;
    for wf in WorkflowId::ALL {
        for obj in Objective::ALL {
            let ceal = run_campaign(Algo::Ceal, &quick(wf, obj, 25, 6));
            let rs = run_campaign(Algo::Rs, &quick(wf, obj, 25, 6));
            ceal_sum += ceal.mean_norm_best();
            rs_sum += rs.mean_norm_best();
        }
    }
    assert!(
        ceal_sum < rs_sum,
        "CEAL mean normalized {ceal_sum} should beat RS {rs_sum}"
    );
}

#[test]
fn history_helps_ceal_and_beats_alph() {
    // paper §7.5 qualitative claims at reduced scale, LV computer time.
    let with = run_campaign(Algo::CealHist, &quick(WorkflowId::LV, Objective::CompTime, 25, 8));
    let without = run_campaign(Algo::Ceal, &quick(WorkflowId::LV, Objective::CompTime, 25, 8));
    let alph = run_campaign(Algo::AlphHist, &quick(WorkflowId::LV, Objective::CompTime, 25, 8));
    assert!(
        with.mean_best() <= without.mean_best() * 1.05,
        "history should help: {} vs {}",
        with.mean_best(),
        without.mean_best()
    );
    assert!(
        with.mean_best() < alph.mean_best(),
        "CEAL+hist {} should beat ALpH+hist {}",
        with.mean_best(),
        alph.mean_best()
    );
}

#[test]
fn experiment_harness_smoke() {
    // every table/figure must run end-to-end at tiny settings and emit
    // its CSV
    let dir = std::env::temp_dir().join(format!("ceal-e2e-{}", std::process::id()));
    let mut ctx = ExpCtx::default();
    ctx.out_dir = dir.clone();
    ctx.reps = 2;
    ctx.pool_size = 120;
    ctx.threads = 2;
    exper::run_table(1, &ctx);
    exper::run_table(2, &ctx);
    for fig in [4usize, 5, 8] {
        assert!(exper::run_fig(fig, &ctx), "fig {fig} missing");
    }
    for name in ["table1.csv", "table2.csv", "fig04.csv", "fig05.csv", "fig08.csv"] {
        let p = dir.join(name);
        assert!(p.exists(), "{} not written", p.display());
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.lines().count() > 1, "{name} is empty");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn payoff_metric_end_to_end() {
    // Fig. 8-style: with history on LV comp time, CEAL should pay off
    // within a finite number of runs at reduced scale.
    let agg = run_campaign(Algo::CealHist, &quick(WorkflowId::LV, Objective::CompTime, 30, 8));
    if let Some(p) = agg.payoff_runs() {
        assert!(p > 0.0 && p < 1e7, "payoff {p} out of range");
    }
    // cost must include only workflow runs when history is free
    for r in &agg.reps {
        assert!(r.cost > 0.0);
        assert!(r.workflow_runs >= 25, "hist variant should spend budget on workflow runs");
    }
}
