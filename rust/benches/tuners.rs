//! End-to-end tuner benchmark: one full tuning run per algorithm at the
//! paper's settings (LV / computer time / m = 50 / pool 2000) — the
//! whole-campaign wall clock the coordinator must sustain.

use ceal::config::WorkflowId;
use ceal::sim::Objective;
use ceal::surrogate::Scorer;
use ceal::tuner::{
    ActiveLearning, Alph, Ceal, CealParams, Geist, Pool, Problem, RandomSampling, Tuner,
};
use ceal::util::bench::Bencher;
use ceal::util::rng::Pcg32;

fn main() {
    let prob = Problem::new(WorkflowId::LV, Objective::CompTime);
    let pool = Pool::generate(&prob, 2000, 0xCEA1);
    pool.knn_graph(10); // prebuild GEIST's graph, as campaigns do
    let scorer = Scorer::Native;
    let tuners: Vec<(&str, Box<dyn Tuner>)> = vec![
        ("RS", Box::new(RandomSampling)),
        ("AL", Box::new(ActiveLearning::default())),
        ("GEIST", Box::new(Geist::default())),
        ("CEAL", Box::new(Ceal::new(CealParams::no_hist()))),
        ("ALpH", Box::new(Alph::new(CealParams::no_hist()))),
    ];
    let mut b = Bencher::from_env(1, 10);
    for (name, tuner) in &tuners {
        let mut rep = 0u64;
        b.bench(&format!("tuner/{name}/m50_pool2000"), || {
            rep += 1;
            let mut rng = Pcg32::new(0xBEEF ^ rep, 0);
            tuner.run(&prob, &pool, &scorer, 50, &mut rng)
        });
    }

    // Thread-sweep rows: one CEAL cell at pinned fork-join widths —
    // the inner loop (GBT training, pool scoring, batch measurement)
    // is what scales; results are bit-identical across the sweep.
    let sweep_prob = Problem::new(WorkflowId::LV, Objective::CompTime);
    let sweep_pool = Pool::generate(&sweep_prob, 1000, 0xCEA1);
    for t in [1usize, 4, 8] {
        ceal::util::parallel::with_threads(t, || {
            let tuner = Ceal::new(CealParams::no_hist());
            let mut rep = 0u64;
            b.bench(&format!("tuner/CEAL/LV_m30_pool1000_t{t}"), || {
                rep += 1;
                let mut rng = Pcg32::new(0xFADE ^ rep, 0);
                tuner.run(&sweep_prob, &sweep_pool, &scorer, 30, &mut rng)
            });
        });
    }

    // Ask/tell driver overhead: the frozen monolithic reference loop
    // vs drive(session, Collector) at the same cell — bit-identical
    // outputs, so any wall-clock gap is pure session machinery.
    {
        use ceal::tuner::{drive, legacy, Collector};
        let tuner = Ceal::new(CealParams::no_hist());
        let mut rep = 0u64;
        b.bench("tuner/CEAL/LV_m30_pool1000_monolithic", || {
            rep += 1;
            let mut rng = Pcg32::new(0xD1CE ^ rep, 0);
            legacy::run_ceal(&tuner, &sweep_prob, &sweep_pool, &scorer, 30, &mut rng)
        });
        let mut rep = 0u64;
        b.bench("tuner/CEAL/LV_m30_pool1000_session", || {
            rep += 1;
            let mut rng = Pcg32::new(0xD1CE ^ rep, 0);
            let mut col = Collector::new(&sweep_prob, rng.derive_str("collector"));
            drive(
                tuner.session(&sweep_prob, &sweep_pool, &scorer, 30, &mut rng),
                &mut col,
            )
        });
    }

    // Fault-injected CEAL at the sweep cell: retry/backoff, the
    // outlier gate and the injector's per-request fate derivation all
    // sit on the measurement path, so their overhead vs the clean
    // session row above is the cost of fault tolerance.
    {
        use ceal::tuner::{drive, Collector, FailurePolicy, FaultInjector, FaultPlan};
        let tuner = Ceal::new(CealParams::no_hist());
        let mut rep = 0u64;
        b.bench("tuner/CEAL/LV_m30_pool1000_faults20", || {
            rep += 1;
            let mut rng = Pcg32::new(0xD1CE ^ rep, 0);
            let mut col = Collector::new(&sweep_prob, rng.derive_str("collector"));
            let mut session = tuner.session(&sweep_prob, &sweep_pool, &scorer, 30, &mut rng);
            session.set_failure_policy(FailurePolicy::fault_tolerant());
            let mut injector =
                FaultInjector::new(&mut col, FaultPlan::transient(0.2, 0.05), 7 ^ rep);
            drive(session, &mut injector)
        });
    }

    // Journaled session at the sweep cell: the same drive with a
    // write-ahead journal (fsync per record, periodic snapshot
    // compaction) — its gap vs the clean session row is the price of
    // crash safety.
    {
        use ceal::tuner::{drive_checkpointed, Collector, SessionJournal, TraceHeader};
        let tuner = Ceal::new(CealParams::no_hist());
        let header = TraceHeader {
            algo: "CEAL".into(),
            workflow: "LV".into(),
            objective: "comp_time".into(),
            m: 30,
            pool_size: 1000,
            seed: 0xCEA1,
            scorer: "native".into(),
            ceal_params: None,
            faults: None,
        };
        let dir = std::env::temp_dir().join(format!("ceal-bench-journal-{}", std::process::id()));
        let mut rep = 0u64;
        b.bench("tuner/CEAL/LV_m30_pool1000_journaled", || {
            rep += 1;
            let mut journal = SessionJournal::create(&dir, &header, 0).unwrap();
            let mut rng = Pcg32::new(0xD1CE ^ rep, 0);
            let mut col = Collector::new(&sweep_prob, rng.derive_str("collector"));
            drive_checkpointed(
                tuner.session(&sweep_prob, &sweep_pool, &scorer, 30, &mut rng),
                &mut col,
                &mut journal,
            )
        });
        let _ = std::fs::remove_dir_all(&dir);
    }

    // Served session at the sweep cell: the same journaled drive pushed
    // through the ask/tell wire protocol (in-process transport, one
    // daemon-side journal per session) — its gap vs the journaled row
    // is the price of the codec + session-multiplexing machinery.
    {
        use ceal::coordinator::{session_rng, Algo};
        use ceal::serve::{Loopback, OpenSpec, ServeClient, SessionManager};
        use ceal::tuner::Collector;
        let root = std::env::temp_dir().join(format!("ceal-bench-serve-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let mgr = SessionManager::new(&root, 1, None).unwrap();
        let spec = OpenSpec {
            workflow: "LV".into(),
            objective: "comp_time".into(),
            algo: "CEAL".into(),
            m: 30,
            pool_size: 1000,
            seed: 0xCEA1,
            scorer: "native".into(),
        };
        b.bench("serve/ask_tell_roundtrip", || {
            let mut client = ServeClient::new(Loopback(&mgr));
            client.open(&spec).unwrap();
            let mut rng = session_rng(0xCEA1, Algo::Ceal, 0);
            let mut col = Collector::new(&sweep_prob, rng.derive_str("collector"));
            client.drive(&mut col, None).unwrap()
        });
        let _ = std::fs::remove_dir_all(&root);
    }

    // Large-pool amortized cell: a full CEAL run at pool 1e5 (lazy
    // candidate generation, no materialized truth).  Each iteration's
    // selection re-ranks into the pool-resident codes and each refit
    // extends the session's binned dataset, so this row tracks the
    // end-to-end payoff of the amortized refit path at the scale it
    // was built for.
    {
        let big_prob = Problem::new(WorkflowId::LV, Objective::CompTime);
        let big_pool = Pool::generate_lazy(&big_prob, 100_000, 0xCEA1);
        let tuner = Ceal::new(CealParams::no_hist());
        let mut rep = 0u64;
        b.bench("tuner/CEAL/LV_m30_pool100000_amortized", || {
            rep += 1;
            let mut rng = Pcg32::new(0xFA57 ^ rep, 0);
            tuner.run(&big_prob, &big_pool, &scorer, 30, &mut rng)
        });
    }

    // Registry-added scenario cells (CEAL vs RS) so new-workflow wiring
    // shows up in every bench run: the CH5 deep chain and DM4 diamond.
    for id in [WorkflowId::CH5, WorkflowId::DM4] {
        let prob = Problem::new(id, Objective::ExecTime);
        let pool = Pool::generate(&prob, 1000, 0xCEA1);
        let pair: Vec<(&str, Box<dyn Tuner>)> = vec![
            ("RS", Box::new(RandomSampling)),
            ("CEAL", Box::new(Ceal::new(CealParams::no_hist()))),
        ];
        for (name, tuner) in &pair {
            let mut rep = 0u64;
            b.bench(&format!("tuner/{name}/{id}_m30_pool1000"), || {
                rep += 1;
                let mut rng = Pcg32::new(0xBEEF ^ rep, 1);
                tuner.run(&prob, &pool, &scorer, 30, &mut rng)
            });
        }
    }
}
