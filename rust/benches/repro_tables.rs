//! Reproduction benchmark: time to regenerate each paper table/figure
//! at reduced repetition counts — one bench per experiment, so `cargo
//! bench` covers every table AND figure end-to-end.

use ceal::coordinator::ScorerKind;
use ceal::exper::{self, ExpCtx};
use ceal::util::bench::Bencher;

fn quick_ctx() -> ExpCtx {
    let mut ctx = ExpCtx::default();
    ctx.out_dir = std::env::temp_dir().join("ceal-bench-results");
    ctx.reps = 3;
    ctx.pool_size = 400;
    ctx.threads = 1;
    ctx.scorer = ScorerKind::Native;
    ctx
}

/// One reduced campaign cell (CEAL + RS) on a registry scenario —
/// keeps BENCH rows tracking the non-paper workflows end to end.
fn scenario_cell(wf: ceal::config::WorkflowId, ctx: &ExpCtx) {
    use ceal::coordinator::Algo;
    use ceal::sim::Objective;
    for algo in [Algo::Ceal, Algo::Rs] {
        ctx.run_cell(algo, wf, Objective::ExecTime, 20);
    }
}

/// Silence the experiment's stdout chatter while timing it.
fn main() {
    let ctx = quick_ctx();
    let mut b = Bencher::from_env(0, 3);
    b.bench("repro/table1", || exper::table1::run(&ctx));
    b.bench("repro/table2", || exper::table2::run(&ctx));
    b.bench("repro/fig04", || exper::fig04::run(&ctx));
    b.bench("repro/fig05", || exper::fig05::run(&ctx));
    b.bench("repro/fig06", || exper::fig06::run(&ctx));
    b.bench("repro/fig07", || exper::fig07::run(&ctx));
    b.bench("repro/fig08", || exper::fig08::run(&ctx));
    b.bench("repro/fig09", || exper::fig09::run(&ctx));
    b.bench("repro/fig10", || exper::fig10::run(&ctx));
    b.bench("repro/fig11", || exper::fig11::run(&ctx));
    b.bench("repro/fig12", || exper::fig12::run(&ctx));
    b.bench("repro/fig13", || exper::fig13::run(&ctx));
    b.bench("repro/scenario_ch5", || {
        scenario_cell(ceal::config::WorkflowId::CH5, &ctx)
    });
    b.bench("repro/scenario_dm4", || {
        scenario_cell(ceal::config::WorkflowId::DM4, &ctx)
    });
    println!("\n(reduced settings: reps=3, pool=400 — `ceal all` runs the full versions)");
}
