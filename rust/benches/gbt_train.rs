//! GBT training benchmark: the modeler's cost at the paper's budgets
//! (25-100 workflow samples), at component-history scale (500), and at
//! pool scale (2000).
//!
//! `gbt/train_log/*` is the production histogram engine;
//! `gbt/train_log_exact/*` (run at the two large sizes) is the
//! pre-histogram brute-force engine kept as `train_exact`, so the
//! speedup ratio is measurable in a single run.  Likewise
//! `gbt/native_predict*` compares the blocked batch predictor against
//! the row-at-a-time path.

use ceal::config::F_MAX;
use ceal::gbt::{train_log, train_log_binned, train_log_exact, BinnedDataset, GbtParams};
use ceal::util::bench::Bencher;
use ceal::util::rng::Pcg32;

fn data(rng: &mut Pcg32, n: usize) -> (Vec<[f32; F_MAX]>, Vec<f64>) {
    let xs: Vec<[f32; F_MAX]> = (0..n)
        .map(|_| {
            let mut x = [0f32; F_MAX];
            for v in x.iter_mut() {
                *v = rng.f32();
            }
            x
        })
        .collect();
    let y: Vec<f64> = xs
        .iter()
        .map(|x| 10.0 + 80.0 * x[0] as f64 + 20.0 * (x[1] as f64) * (x[2] as f64))
        .collect();
    (xs, y)
}

fn main() {
    let mut rng = Pcg32::new(0x6B, 0);
    let mut b = Bencher::from_env(2, 15);
    for n in [25usize, 50, 100, 500, 2000] {
        let (xs, y) = data(&mut rng, n);
        let params = if n >= 200 {
            GbtParams::default()
        } else {
            GbtParams::small_data()
        };
        b.bench_items(&format!("gbt/train_log/n{n}"), n as f64, || {
            train_log(&xs, &y, 7, &params)
        });
        // exact-engine baseline at the sizes the histogram engine is
        // built for (it dominates total campaign time there)
        if n >= 500 {
            b.bench_items(&format!("gbt/train_log_exact/n{n}"), n as f64, || {
                train_log_exact(&xs, &y, 7, &params)
            });
        }
    }
    // prediction throughput of the native mirror: blocked batch path
    // vs the row-at-a-time baseline
    let (xs, y) = data(&mut rng, 500);
    let ens = train_log(&xs, &y, 7, &GbtParams::default());
    let (pool, _) = data(&mut rng, 2000);
    b.bench_items("gbt/native_predict/pool2000", 2000.0, || {
        ens.predict_batch(&pool)
    });
    b.bench_items("gbt/native_predict_rowwise/pool2000", 2000.0, || {
        pool.iter().map(|x| ens.predict(x)).collect::<Vec<f32>>()
    });
    let flat = ens.flatten();
    b.bench_items("gbt/flatten", 1.0, || ens.flatten());
    b.bench_items("gbt/flat_predict/pool2000", 2000.0, || {
        flat.predict_batch(&pool)
    });
    b.bench_items("gbt/flat_predict_rowwise/pool2000", 2000.0, || {
        pool.iter().map(|x| flat.predict(x)).collect::<Vec<f32>>()
    });

    // Thread-sweep rows: the same pool-scale training call at pinned
    // fork-join widths, so the scaling curve is measurable in one run
    // (outputs are bit-identical across the sweep by contract).
    let (sx, sy) = data(&mut rng, 2000);
    for t in [1usize, 4, 8] {
        ceal::util::parallel::with_threads(t, || {
            b.bench_items(&format!("gbt/train_log/n2000_t{t}"), 2000.0, || {
                train_log(&sx, &sy, 7, &GbtParams::default())
            });
        });
    }

    // Incremental-refit row: the dataset is binned once (outside the
    // timed row, as `IncrementalTrainer` retains it across a session's
    // refits) and each iteration pays only the training sweep —
    // against `gbt/train_log/n2000`, the gap is the per-refit
    // sort+bin cost the amortization removes.
    let params = GbtParams::default();
    let binned = BinnedDataset::build(&sx, 7, params.n_bins);
    b.bench_items("gbt/train_log/n2000_incr", 2000.0, || {
        train_log_binned(&binned, &sy, 7, &params)
    });
}
