//! Simulator throughput: workflow runs per second (the collector's
//! cost driver), the pipeline DES in isolation, and pool generation
//! (2000-config test sets with ground truth).

use ceal::config::WorkflowId;
use ceal::sim::Objective;
use ceal::tuner::{Pool, Problem};
use ceal::util::bench::Bencher;
use ceal::util::rng::Pcg32;

fn main() {
    let mut b = Bencher::from_env(3, 30);
    for id in WorkflowId::ALL {
        let prob = Problem::new(id, Objective::ExecTime);
        let mut rng = Pcg32::new(1, 0);
        let feasible = |c: &ceal::config::Config| prob.sim.feasible(c);
        let cfgs: Vec<_> = (0..256)
            .map(|_| prob.sim.spec.sample_feasible(&mut rng, &feasible, 100_000))
            .collect();
        let mut run_rng = Pcg32::new(2, 0);
        let mut i = 0usize;
        b.bench_items(&format!("sim/{}/noisy_run", id.name()), 1.0, || {
            i = (i + 1) % cfgs.len();
            prob.sim.run(&cfgs[i], &mut run_rng)
        });
        let mut j = 0usize;
        b.bench_items(&format!("sim/{}/expected_run", id.name()), 1.0, || {
            j = (j + 1) % cfgs.len();
            prob.sim.expected(&cfgs[j])
        });
        let mut k = 0usize;
        b.bench_items(&format!("sim/{}/pipeline_only", id.name()), 1.0, || {
            k = (k + 1) % cfgs.len();
            prob.sim.build_pipeline(&cfgs[k]).simulate()
        });
    }
    let prob = Problem::new(WorkflowId::Lv, Objective::CompTime);
    let mut bslow = Bencher::from_env(1, 5);
    bslow.bench_items("pool/generate2000_with_truth", 2000.0, || {
        Pool::generate(&prob, 2000, 7)
    });
}
