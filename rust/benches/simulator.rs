//! Simulator throughput: workflow runs per second (the collector's
//! cost driver), the pipeline DES in isolation, and pool generation
//! (2000-config test sets with ground truth).
//!
//! Before/after rows for the allocation-free hot path sit side by side:
//! `pipeline_only` builds the reference `Pipeline` and simulates with
//! full matrices (the old path), `noisy_run`/`expected_run` drive the
//! structure+workspace path with an explicitly cold workspace per call
//! (isolating the allocation overhead), and `*_reused` thread one warm
//! workspace through every call like a collector does (the tuner-facing
//! `run()`/`expected()` wrappers also run warm, via a per-thread
//! scratch workspace).  `pool/cached_lookup` measures a PoolCache hit
//! against `pool/generate2000_with_truth` (a miss / the old
//! per-algorithm cost).

use ceal::config::WorkflowId;
use ceal::coordinator::poolcache::PoolCache;
use ceal::sim::{Objective, SimWorkspace};
use ceal::tuner::{Pool, Problem};
use ceal::util::bench::Bencher;
use ceal::util::rng::Pcg32;

fn main() {
    let mut b = Bencher::from_env(3, 30);
    // every registered workflow: the paper trio + CH5/DM4 scenarios
    for id in ceal::sim::WorkflowRegistry::global().ids() {
        let prob = Problem::new(id, Objective::ExecTime);
        let mut rng = Pcg32::new(1, 0);
        let feasible = |c: &ceal::config::Config| prob.sim.feasible(c);
        let cfgs: Vec<_> = (0..256)
            .map(|_| prob.sim.spec.sample_feasible(&mut rng, &feasible, 100_000))
            .collect();
        let mut run_rng = Pcg32::new(2, 0);
        let mut i = 0usize;
        b.bench_items(&format!("sim/{}/noisy_run", id.name()), 1.0, || {
            i = (i + 1) % cfgs.len();
            prob.sim
                .run_with(&cfgs[i], &mut run_rng, &mut SimWorkspace::new())
        });
        let mut reuse_rng = Pcg32::new(2, 0);
        let mut ws = SimWorkspace::new();
        let mut ir = 0usize;
        b.bench_items(&format!("sim/{}/noisy_run_reused", id.name()), 1.0, || {
            ir = (ir + 1) % cfgs.len();
            prob.sim.run_with(&cfgs[ir], &mut reuse_rng, &mut ws)
        });
        let mut j = 0usize;
        b.bench_items(&format!("sim/{}/expected_run", id.name()), 1.0, || {
            j = (j + 1) % cfgs.len();
            prob.sim.expected_with(&cfgs[j], &mut SimWorkspace::new())
        });
        let mut wse = SimWorkspace::new();
        let mut je = 0usize;
        b.bench_items(&format!("sim/{}/expected_run_reused", id.name()), 1.0, || {
            je = (je + 1) % cfgs.len();
            prob.sim.expected_with(&cfgs[je], &mut wse)
        });
        // reference path: per-run Pipeline construction + full-matrix
        // simulate — the pre-workspace baseline
        let mut k = 0usize;
        b.bench_items(&format!("sim/{}/pipeline_only", id.name()), 1.0, || {
            k = (k + 1) % cfgs.len();
            prob.sim.build_pipeline(&cfgs[k]).simulate()
        });
    }
    let prob = Problem::new(WorkflowId::LV, Objective::CompTime);
    let mut bslow = Bencher::from_env(1, 5);
    bslow.bench_items("pool/generate2000_with_truth", 2000.0, || {
        Pool::generate(&prob, 2000, 7)
    });
    let threads = ceal::coordinator::campaign::default_threads();
    bslow.bench_items(
        &format!("pool/generate2000_par{threads}"),
        2000.0,
        || Pool::generate_par(&prob, 2000, 7, threads),
    );
    let cache = PoolCache::new();
    cache.get_or_generate(&prob, 2000, 7, threads); // warm the cell
    let mut bfast = Bencher::from_env(3, 30);
    bfast.bench_items("pool/cached_lookup", 2000.0, || {
        cache.get_or_generate(&prob, 2000, 7, threads)
    });
    // Million-config candidate generation: lazy pools sample and
    // encode the full candidate stream but never run the simulator,
    // so this measures the sampling+dedup+encoding side alone.
    let mut blazy = Bencher::from_env(1, 2);
    blazy.bench_items("pool/lazy_generate1e6", 1_000_000.0, || {
        Pool::generate_lazy(&prob, 1_000_000, 7)
    });
}
