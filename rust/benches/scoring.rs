//! Hot-path benchmark: surrogate scoring of the configuration pool —
//! the operation CEAL repeats on every iteration (Alg. 1 lines 10/23).
//! Compares the PJRT artifact path against the native mirror, at pool
//! and small-batch sizes, plus the fused low-fidelity combination.

use ceal::config::{lv_spec, Config, F_MAX};
use ceal::gbt::{train_log, GbtParams, PoolCodes, QuantizedEnsemble};
use ceal::runtime::Runtime;
use ceal::sim::Objective;
use ceal::surrogate::{PoolFeatures, Scorer};
use ceal::util::bench::Bencher;
use ceal::util::rng::Pcg32;

fn main() {
    let mut b = Bencher::from_env(3, 20);
    let spec = lv_spec();
    let mut rng = Pcg32::new(0xBE, 0);
    let configs: Vec<Config> = (0..2000).map(|_| spec.sample(&mut rng)).collect();
    let feats = PoolFeatures::encode(&spec, &configs);

    // realistically-trained log-space models
    let xs: Vec<[f32; F_MAX]> = feats.workflow.iter().take(50).cloned().collect();
    let y: Vec<f64> = xs
        .iter()
        .map(|x| (10.0 + 50.0 * x[0] as f64).max(1.0))
        .collect();
    let ens = train_log(&xs, &y, 7, &GbtParams::small_data());
    let cx: Vec<[f32; F_MAX]> = feats.per_component[0].iter().take(200).cloned().collect();
    let cy: Vec<f64> = cx
        .iter()
        .map(|x| (5.0 + 20.0 * x[0] as f64).max(1.0))
        .collect();
    let comp0 = train_log(&cx, &cy, 4, &GbtParams::small_data());
    let comp1 = comp0.clone();

    println!("== pool scoring (2000 configs x 64-tree ensemble) ==");
    let native = Scorer::Native;
    b.bench_items("native/pool2000", 2000.0, || {
        native.score(&ens, &feats.workflow)
    });
    // row-at-a-time baseline for the blocked batch path above
    b.bench_items("native/pool2000_rowwise", 2000.0, || {
        feats
            .workflow
            .iter()
            .map(|x| ens.predict(x) as f64)
            .collect::<Vec<f64>>()
    });
    b.bench_items("native/batch256", 256.0, || {
        native.score(&ens, &feats.workflow[..256])
    });
    b.bench_items("native/lowfi2000", 2000.0, || {
        native.lowfi(&[comp0.clone(), comp1.clone()], &feats, Objective::CompTime)
    });

    // Thread-sweep rows: artifact-shaped full-pool scoring at pinned
    // fork-join widths (bit-identical outputs across the sweep).
    let flat = ens.flatten();
    for t in [1usize, 4, 8] {
        ceal::util::parallel::with_threads(t, || {
            b.bench_items(&format!("scoring/flat_predict/pool2000_t{t}"), 2000.0, || {
                flat.predict_batch(&feats.workflow)
            });
        });
    }

    // Million-config pool rows at 1e5 candidates: the quantized SoA
    // path against the dense flat baseline, plus the once-per-refit
    // build cost.  Candidates are sampled without the feasibility
    // filter — feature encoding is all that scoring exercises.
    println!("== pool scoring at 1e5 configs (quantized SoA vs flat) ==");
    let big_configs: Vec<Config> = (0..100_000).map(|_| spec.sample(&mut rng)).collect();
    let big = PoolFeatures::encode(&spec, &big_configs);
    ceal::util::parallel::with_threads(1, || {
        b.bench_items("scoring/flat_predict/pool1e5_t1", 100_000.0, || {
            flat.predict_batch(&big.workflow)
        });
    });
    b.bench_items("scoring/quantized_build/pool1e5", 100_000.0, || {
        QuantizedEnsemble::build(&ens, &big.workflow)
    });
    // Amortized refit path: the pool codes are built once (outside the
    // timed row), then each refit only re-ranks the fresh ensemble's
    // thresholds into them — the per-iteration cost that replaces
    // `quantized_build` above.
    let pool_codes = std::sync::Arc::new(PoolCodes::build(&big.workflow));
    b.bench_items("scoring/quantized_rerank/pool1e5", 100_000.0, || {
        QuantizedEnsemble::rerank(&ens, &pool_codes)
    });
    let quant = QuantizedEnsemble::build(&ens, &big.workflow);
    for t in [1usize, 4, 8] {
        ceal::util::parallel::with_threads(t, || {
            b.bench_items(
                &format!("scoring/quantized_predict/pool1e5_t{t}"),
                100_000.0,
                || quant.predict_all(),
            );
        });
    }

    match Runtime::load_default() {
        Ok(rt) => {
            let pjrt = Scorer::Pjrt(rt);
            b.bench_items("pjrt/pool2000", 2000.0, || pjrt.score(&ens, &feats.workflow));
            b.bench_items("pjrt/batch256", 256.0, || {
                pjrt.score(&ens, &feats.workflow[..256])
            });
            b.bench_items("pjrt/lowfi2000", 2000.0, || {
                pjrt.lowfi(&[comp0.clone(), comp1.clone()], &feats, Objective::CompTime)
            });
        }
        Err(e) => println!("(pjrt benches skipped: {e:#})"),
    }
}
