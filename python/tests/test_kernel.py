"""Pallas kernel vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps shapes (N, F, T, D, block size) and data; every case
asserts allclose between the interpret-mode Pallas kernel and ref.py.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import gbt_predict as gk
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def make_case(rng, n, f, trees, depth):
    """Random ensemble + data with thresholds in data range."""
    x = rng.uniform(0.0, 1.0, size=(n, f)).astype(np.float32)
    feat = rng.integers(0, f, size=(trees, depth)).astype(np.int32)
    thr = rng.uniform(0.0, 1.0, size=(trees, depth)).astype(np.float32)
    leaves = rng.normal(0.0, 1.0, size=(trees, 1 << depth)).astype(np.float32)
    return x, feat, thr, leaves


@pytest.mark.parametrize("n,block_n", [(8, 8), (64, 32), (256, 64), (512, 256)])
@pytest.mark.parametrize("trees,depth", [(1, 1), (4, 3), (16, 6)])
def test_kernel_matches_ref_grid(n, block_n, trees, depth):
    rng = np.random.default_rng(n * 1000 + trees * 10 + depth)
    f = 8
    x, feat, thr, leaves = make_case(rng, n, f, trees, depth)
    got = gk.ensemble_predict(x, feat, thr, leaves, block_n=block_n)
    want = ref.ensemble_predict_ref(x, feat, thr, leaves)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n_blocks=st.integers(1, 4),
    block_n=st.sampled_from([8, 16, 32]),
    f=st.integers(1, 8),
    trees=st.integers(1, 12),
    depth=st.integers(1, 6),
)
def test_kernel_matches_ref_hypothesis(seed, n_blocks, block_n, f, trees, depth):
    rng = np.random.default_rng(seed)
    n = n_blocks * block_n
    x, feat, thr, leaves = make_case(rng, n, f, trees, depth)
    got = gk.ensemble_predict(x, feat, thr, leaves, block_n=block_n)
    want = ref.ensemble_predict_ref(x, feat, thr, leaves)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_padding_trees_are_neutral():
    """Unused trees (thr=+inf, leaves=0) must contribute exactly 0."""
    rng = np.random.default_rng(7)
    n, f, trees, depth = 32, 8, 8, 4
    x, feat, thr, leaves = make_case(rng, n, f, trees, depth)
    # Pad: double the tree count with +inf thresholds and zero leaves.
    feat2 = np.concatenate([feat, np.zeros_like(feat)], axis=0)
    thr2 = np.concatenate([thr, np.full_like(thr, np.inf)], axis=0)
    leaves2 = np.concatenate([leaves, np.zeros_like(leaves)], axis=0)
    got = gk.ensemble_predict(x, feat2, thr2, leaves2, block_n=32)
    want = ref.ensemble_predict_ref(x, feat, thr, leaves)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_bias_tree_constant_leaves():
    """Bias convention: a tree with constant leaves adds the constant."""
    n, f, trees, depth = 16, 4, 1, 3
    x = np.random.default_rng(0).uniform(size=(n, f)).astype(np.float32)
    feat = np.zeros((trees, depth), np.int32)
    thr = np.full((trees, depth), np.inf, np.float32)
    leaves = np.full((trees, 1 << depth), 3.25, np.float32)
    got = gk.ensemble_predict(x, feat, thr, leaves, block_n=16)
    np.testing.assert_allclose(np.asarray(got), np.full((n,), 3.25), rtol=1e-6)


def test_single_split_partitions_batch():
    """One depth-1 tree is a step function on the split feature."""
    n = 32
    x = np.linspace(0.0, 1.0, n, dtype=np.float32).reshape(n, 1)
    feat = np.zeros((1, 1), np.int32)
    thr = np.full((1, 1), 0.5, np.float32)
    leaves = np.array([[-1.0, 2.0]], np.float32)
    got = np.asarray(gk.ensemble_predict(x, feat, thr, leaves, block_n=n))
    want = np.where(x[:, 0] > 0.5, 2.0, -1.0)
    np.testing.assert_allclose(got, want)


def test_block_n_must_divide_n():
    with pytest.raises(ValueError):
        gk.make_ensemble_predict(100, 8, 4, 3, block_n=64)


def test_default_artifact_shape_runs():
    """The exact artifact shape (N=2048, F=8, T=64, D=6) round-trips."""
    rng = np.random.default_rng(42)
    x, feat, thr, leaves = make_case(rng, gk.POOL_N, gk.F_MAX, gk.T_TREES, gk.DEPTH)
    got = gk.ensemble_predict(x, feat, thr, leaves)
    want = ref.ensemble_predict_ref(x, feat, thr, leaves)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)
