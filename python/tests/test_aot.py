"""AOT pipeline tests: lowering produces loadable HLO text with the
expected entry signature, and the manifest matches the kernel constants."""

import re

import jax
import numpy as np
import pytest

from compile import aot
from compile.kernels import gbt_predict as gk

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def small_hlo():
    return aot.to_hlo_text(aot.lower_ensemble_predict(gk.SMALL_N))


def test_hlo_text_nonempty(small_hlo):
    assert "HloModule" in small_hlo
    assert len(small_hlo) > 1000


def test_hlo_entry_signature(small_hlo):
    """Entry takes (x, feat, thr, leaves) with the artifact shapes and
    returns a 1-tuple of f32[N] (return_tuple=True convention)."""
    assert f"f32[{gk.SMALL_N},{gk.F_MAX}]" in small_hlo
    assert f"s32[{gk.T_TREES},{gk.DEPTH}]" in small_hlo
    assert f"f32[{gk.T_TREES},{1 << gk.DEPTH}]" in small_hlo
    assert re.search(
        rf"\(f32\[{gk.SMALL_N}\](\{{0\}})?\)", small_hlo
    ), "tupled f32[N] output"


def test_hlo_has_no_custom_calls(small_hlo):
    """interpret=True must lower to plain HLO ops — a Mosaic custom-call
    would be unexecutable on the CPU PJRT plugin."""
    assert "custom-call" not in small_hlo


def test_lowfi_hlo_signature():
    text = aot.to_hlo_text(aot.lower_lowfi_score(gk.SMALL_N))
    assert f"f32[{aot.J_MAX},{gk.SMALL_N},{gk.F_MAX}]" in text
    assert "custom-call" not in text


def test_meta_matches_constants():
    meta = aot.build_meta()
    assert meta["pool_n"] == gk.POOL_N
    assert meta["small_n"] == gk.SMALL_N
    assert meta["trees"] == gk.T_TREES
    assert meta["leaves"] == (1 << gk.DEPTH)
    assert set(meta["artifacts"]) == set(aot.ARTIFACTS)


def test_compiled_artifact_matches_ref():
    """Execute the lowered small artifact via jax and compare to ref —
    guards the whole lowering chain, not just the kernel."""
    from compile.kernels import ref

    rng = np.random.default_rng(5)
    n, f, t, d = gk.SMALL_N, gk.F_MAX, gk.T_TREES, gk.DEPTH
    x = rng.uniform(size=(n, f)).astype(np.float32)
    feat = rng.integers(0, f, size=(t, d)).astype(np.int32)
    thr = rng.uniform(size=(t, d)).astype(np.float32)
    leaves = rng.normal(size=(t, 1 << d)).astype(np.float32)
    compiled = aot.lower_ensemble_predict(n).compile()
    (got,) = compiled(x, feat, thr, leaves)
    want = ref.ensemble_predict_ref(x, feat, thr, leaves)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)
