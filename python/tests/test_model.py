"""L2 graph tests: low-fidelity combination (Eqns 1-2) and padding."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import gbt_predict as gk
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def make_components(rng, j, n, f, trees, depth):
    xs = rng.uniform(0.0, 1.0, size=(j, n, f)).astype(np.float32)
    feats = rng.integers(0, f, size=(j, trees, depth)).astype(np.int32)
    thrs = rng.uniform(0.0, 1.0, size=(j, trees, depth)).astype(np.float32)
    leaves = rng.normal(1.0, 0.3, size=(j, trees, 1 << depth)).astype(np.float32)
    return xs, feats, thrs, leaves


@pytest.mark.parametrize("mode", [0.0, 1.0])
@pytest.mark.parametrize("j", [1, 2, 4])
def test_lowfi_matches_ref(mode, j):
    rng = np.random.default_rng(j * 17 + int(mode))
    n, f, trees, depth = 64, 8, 6, 4
    xs, feats, thrs, leaves = make_components(rng, j, n, f, trees, depth)
    got = model.lowfi_score(xs, feats, thrs, leaves, jnp.float32(mode), block_n=32)
    want = ref.lowfi_score_ref(xs, feats, thrs, leaves, mode)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_mode_one_is_max_mode_zero_is_sum():
    """mode=1 must equal max over exp(components); mode=0 the sum
    (Eqns 1-2 on log-space model outputs)."""
    rng = np.random.default_rng(3)
    n, f, trees, depth, j = 32, 4, 4, 3, 3
    xs, feats, thrs, leaves = make_components(rng, j, n, f, trees, depth)
    preds = np.exp(
        np.stack(
            [
                np.asarray(
                    ref.ensemble_predict_ref(xs[k], feats[k], thrs[k], leaves[k])
                )
                for k in range(j)
            ]
        )
    )
    got_max = np.asarray(
        model.lowfi_score(xs, feats, thrs, leaves, jnp.float32(1.0), block_n=32)
    )
    got_sum = np.asarray(
        model.lowfi_score(xs, feats, thrs, leaves, jnp.float32(0.0), block_n=32)
    )
    np.testing.assert_allclose(got_max, preds.max(axis=0), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(got_sum, preds.sum(axis=0), rtol=1e-5, atol=1e-5)


def test_padded_components_neutral_for_positive_times():
    """Padding components carry a large-negative constant prediction
    (exp -> 0), so they must not change max or sum of real component
    times (the artifact always carries J=4 slots)."""
    rng = np.random.default_rng(11)
    n, f, trees, depth = 32, 8, 4, 3
    xs, feats, thrs, leaves = make_components(rng, 2, n, f, trees, depth)
    pad = 2
    xs_p = np.concatenate([xs, np.zeros((pad, n, f), np.float32)])
    feats_p = np.concatenate([feats, np.zeros((pad, trees, depth), np.int32)])
    thrs_p = np.concatenate([thrs, np.full((pad, trees, depth), np.inf, np.float32)])
    pad_leaves = np.zeros((pad, trees, 1 << depth), np.float32)
    pad_leaves[:, 0, :] = -1.0e9  # NEG_PRED convention (exp -> 0)
    leaves_p = np.concatenate([leaves, pad_leaves])
    for mode in (0.0, 1.0):
        got = np.asarray(
            model.lowfi_score(xs_p, feats_p, thrs_p, leaves_p, jnp.float32(mode), block_n=32)
        )
        want = np.asarray(ref.lowfi_score_ref(xs, feats, thrs, leaves, mode))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
