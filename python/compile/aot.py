"""AOT pipeline: lower the L2 graphs to HLO *text* artifacts.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the `xla` crate's
bundled xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`).  The HLO
text parser on the Rust side reassigns ids, so text round-trips cleanly.
See /opt/xla-example/README.md.

Lowering path: jax.jit(fn).lower(specs) -> StableHLO module ->
XlaComputation (return_tuple=True; the Rust side unwraps with
to_tuple1()) -> as_hlo_text().

Artifacts (shapes must match rust/src/runtime/mod.rs):
  ensemble_predict.hlo.txt        N=2048  F=8  T=64 D=6
  ensemble_predict_small.hlo.txt  N=256   F=8  T=64 D=6
  lowfi_score.hlo.txt             J=4 N=2048 F=8 T=64 D=6 + mode scalar
  meta.json                       shape manifest consumed by Rust tests

Python runs ONCE at build time (`make artifacts`); the Rust binary is
self-contained afterwards.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import gbt_predict as gk

J_MAX = 4


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_ensemble_predict(n, f=gk.F_MAX, trees=gk.T_TREES, depth=gk.DEPTH):
    leaves_w = 1 << depth
    specs = (
        jax.ShapeDtypeStruct((n, f), jnp.float32),
        jax.ShapeDtypeStruct((trees, depth), jnp.int32),
        jax.ShapeDtypeStruct((trees, depth), jnp.float32),
        jax.ShapeDtypeStruct((trees, leaves_w), jnp.float32),
    )

    def fn(x, feat, thr, leaves):
        return (model.ensemble_predict(x, feat, thr, leaves),)

    return jax.jit(fn).lower(*specs)


def lower_lowfi_score(
    n, j=J_MAX, f=gk.F_MAX, trees=gk.T_TREES, depth=gk.DEPTH
):
    leaves_w = 1 << depth
    specs = (
        jax.ShapeDtypeStruct((j, n, f), jnp.float32),
        jax.ShapeDtypeStruct((j, trees, depth), jnp.int32),
        jax.ShapeDtypeStruct((j, trees, depth), jnp.float32),
        jax.ShapeDtypeStruct((j, trees, leaves_w), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
    )

    def fn(xs, feats, thrs, leaves, mode):
        return (model.lowfi_score(xs, feats, thrs, leaves, mode),)

    return jax.jit(fn).lower(*specs)


ARTIFACTS = {
    "ensemble_predict.hlo.txt": lambda: lower_ensemble_predict(gk.POOL_N),
    "ensemble_predict_small.hlo.txt": lambda: lower_ensemble_predict(gk.SMALL_N),
    "lowfi_score.hlo.txt": lambda: lower_lowfi_score(gk.POOL_N),
}


def build_meta():
    return {
        "pool_n": gk.POOL_N,
        "small_n": gk.SMALL_N,
        "f_max": gk.F_MAX,
        "trees": gk.T_TREES,
        "depth": gk.DEPTH,
        "leaves": 1 << gk.DEPTH,
        "j_max": J_MAX,
        "artifacts": sorted(ARTIFACTS),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    for name, builder in ARTIFACTS.items():
        path = os.path.join(args.out_dir, name)
        text = to_hlo_text(builder())
        with open(path, "w") as fh:
            fh.write(text)
        print(f"wrote {len(text):>9} chars -> {path}")
    meta_path = os.path.join(args.out_dir, "meta.json")
    with open(meta_path, "w") as fh:
        json.dump(build_meta(), fh, indent=2, sort_keys=True)
        fh.write("\n")
    print(f"wrote manifest     -> {meta_path}")


if __name__ == "__main__":
    main()
