"""L1 Pallas kernel: oblivious gradient-boosted-tree ensemble inference.

The surrogate models trained by the Rust coordinator (rust/src/gbt/) are
*oblivious* decision trees: every level of a tree applies the same
(feature, threshold) split to every node at that level.  That makes the
whole ensemble a fixed-shape tensor program —

    features   : [T, D]   int32   feature index tested at (tree, depth)
    thresholds : [T, D]   float32 split threshold at (tree, depth)
    leaves     : [T, 2^D] float32 leaf values per tree

and inference over a batch X[N, F] is, per tree,

    idx = sum_d (X[:, features[t, d]] > thresholds[t, d]) << d
    pred += leaves[t, idx]

which is D vectorized compares + one 2^D-wide gather per tree: dense,
branch-free, VPU-friendly work.  This is the §Hardware-Adaptation story:
the paper's xgboost inference is pointer-chasing on a CPU; on a TPU we
restructure the model so a level is one vector compare over the whole
N-tile and the leaf lookup is a gather from a VMEM-resident [T, 2^D]
table.  The N dimension is tiled with a BlockSpec (HBM->VMEM schedule);
the ensemble tables are small (T=64, D=6 -> 17 KiB of leaves) and are
mapped to block (0, 0) at every grid step, i.e. held in VMEM rather than
re-streamed.

Padding conventions (must match rust/src/gbt/ensemble.rs):
  * unused trees: thresholds = +inf, leaves = 0  -> contribute 0;
  * the ensemble bias is folded into tree 0 as constant leaves;
  * unused features: X column = 0, never selected by real splits.

The kernel MUST be lowered with interpret=True: the CPU PJRT plugin
cannot execute Mosaic custom-calls (real-TPU lowering).  Correctness is
pinned against the pure-jnp oracle in ref.py by python/tests/.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default artifact shape constants — keep in sync with rust/src/runtime/mod.rs.
POOL_N = 2048  # scored pool size (paper: |C_pool| = 2000, padded)
SMALL_N = 256  # small-batch artifact (C_meas scoring, model-switch checks)
F_MAX = 8  # max feature count (Table 1: <= 7 params per workflow view)
T_TREES = 64  # boosting rounds
DEPTH = 6  # oblivious tree depth (2^6 = 64 leaves)
BLOCK_N = 256  # N-tile per grid step


def _predict_kernel(x_ref, feat_ref, thr_ref, leaves_ref, out_ref, *, trees, depth):
    """Pallas kernel body. Shapes: x [BN, F], feat/thr [T, D],
    leaves [T, 2^depth], out [BN]."""
    x = x_ref[...]
    n = x.shape[0]
    acc = jnp.zeros((n,), jnp.float32)
    for t in range(trees):
        idx = jnp.zeros((n,), jnp.int32)
        for d in range(depth):
            f = feat_ref[t, d]
            # Dynamic feature gather: one column of the X tile.
            xv = jnp.take(x, f, axis=1, mode="clip")
            bit = (xv > thr_ref[t, d]).astype(jnp.int32)
            idx = idx + bit * (1 << d)
        acc = acc + jnp.take(leaves_ref[t], idx, mode="clip")
    out_ref[...] = acc


def make_ensemble_predict(n, f, trees, depth, block_n=None, interpret=True):
    """Build the tiled pallas_call for a fixed (n, f, trees, depth).

    Returns fn(x[n,f] f32, feat[trees,depth] i32, thr[trees,depth] f32,
    leaves[trees,2^depth] f32) -> [n] f32.
    """
    if block_n is None:
        block_n = min(BLOCK_N, n)
    if n % block_n != 0:
        raise ValueError(f"n={n} must be a multiple of block_n={block_n}")
    leaves_w = 1 << depth
    grid = (n // block_n,)
    kernel = functools.partial(_predict_kernel, trees=trees, depth=depth)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # X: stream one [block_n, F] tile per grid step.
            pl.BlockSpec((block_n, f), lambda i: (i, 0)),
            # Ensemble tables: same (small) block at every step -> VMEM-resident.
            pl.BlockSpec((trees, depth), lambda i: (0, 0)),
            pl.BlockSpec((trees, depth), lambda i: (0, 0)),
            pl.BlockSpec((trees, leaves_w), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )


def ensemble_predict(x, feat, thr, leaves, block_n=None, interpret=True):
    """Convenience wrapper inferring shapes from the arguments."""
    n, f = x.shape
    trees, depth = feat.shape
    fn = make_ensemble_predict(n, f, trees, depth, block_n=block_n, interpret=interpret)
    return fn(x, feat, thr, leaves)
