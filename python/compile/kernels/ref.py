"""Pure-jnp oracle for the oblivious-GBT ensemble kernels.

This is the correctness ground truth: no pallas, no tiling — just the
mathematical definition of oblivious-tree inference and the Eqn 1/2
low-fidelity combination.  python/tests/ asserts the Pallas kernel
(interpret mode) and the AOT-lowered HLO agree with these functions, and
rust integration tests re-derive the same numbers through the PJRT path.
"""

import jax.numpy as jnp


def ensemble_predict_ref(x, feat, thr, leaves):
    """Reference oblivious-ensemble inference.

    x:      [N, F] float32
    feat:   [T, D] int32 (values in [0, F))
    thr:    [T, D] float32
    leaves: [T, 2^D] float32
    returns [N] float32
    """
    n, _ = x.shape
    trees, depth = feat.shape
    acc = jnp.zeros((n,), jnp.float32)
    for t in range(trees):
        idx = jnp.zeros((n,), jnp.int32)
        for d in range(depth):
            xv = x[:, feat[t, d]]
            idx = idx + (xv > thr[t, d]).astype(jnp.int32) * (1 << d)
        acc = acc + leaves[t][idx]
    return acc


def lowfi_score_ref(xs, feats, thrs, leaves, mode):
    """Reference low-fidelity combination (paper Eqns 1-2).

    xs:    [J, N, F]; feats/thrs: [J, T, D]; leaves: [J, T, 2^D]
    mode:  scalar in {1.0 (max / execution time), 0.0 (sum / computer time)}
    returns [N] float32: mode*max_j exp(P_j) + (1-mode)*sum_j exp(P_j)

    Component models are trained in log space; padding components carry
    a large-negative constant (exp -> 0) so they are neutral.
    """
    j = xs.shape[0]
    preds = jnp.exp(
        jnp.stack(
            [ensemble_predict_ref(xs[k], feats[k], thrs[k], leaves[k]) for k in range(j)]
        )
    )
    return mode * jnp.max(preds, axis=0) + (1.0 - mode) * jnp.sum(preds, axis=0)
