"""L2: the JAX compute graphs the Rust coordinator executes on its hot loop.

Two graphs, both calling the L1 Pallas kernel (kernels/gbt_predict.py):

  * ensemble_predict — score a configuration pool X[N, F] with one
    flattened oblivious-GBT ensemble (the high-fidelity surrogate, or a
    single component model).

  * lowfi_score — the paper's low-fidelity workflow model (§4): run J
    per-component ensembles over their per-component feature views and
    combine with Eqn 1 (max, execution time) or Eqn 2 (sum, computer
    time).  `mode` is a runtime scalar (1.0 -> max, 0.0 -> sum) so a
    single compiled artifact serves both optimization objectives:
    score = mode*max_j exp(P_j) + (1-mode)*sum_j exp(P_j).

Models are trained in LOG space (times span orders of magnitude), so
the combination exponentiates each component prediction back to real
time before taking max/sum.  Padded components (J fixed at 4) carry a
large-negative constant tree (exp -> 0), which is neutral for both
max-over-positive-times and sum.

All shapes are static (AOT); ensembles are runtime *inputs*, so the Rust
side retrains models freely without ever re-lowering or re-compiling.
"""

import jax.numpy as jnp

from .kernels import gbt_predict as gk


def ensemble_predict(x, feat, thr, leaves, block_n=None, interpret=True):
    """Score x[N, F] with one flattened ensemble. Returns [N] f32."""
    return gk.ensemble_predict(
        x, feat, thr, leaves, block_n=block_n, interpret=interpret
    )


def lowfi_score(xs, feats, thrs, leaves, mode, block_n=None, interpret=True):
    """Low-fidelity combined score (Eqns 1-2), one fused graph.

    xs:     [J, N, F] f32 — per-component feature views of the same pool
    feats:  [J, T, D] i32; thrs: [J, T, D] f32; leaves: [J, T, 2^D] f32
    mode:   scalar f32 — 1.0 selects max (exec time), 0.0 selects sum
    returns [N] f32
    """
    j = xs.shape[0]
    preds = jnp.exp(
        jnp.stack(
            [
                gk.ensemble_predict(
                    xs[k],
                    feats[k],
                    thrs[k],
                    leaves[k],
                    block_n=block_n,
                    interpret=interpret,
                )
                for k in range(j)
            ]
        )
    )
    return mode * jnp.max(preds, axis=0) + (1.0 - mode) * jnp.sum(preds, axis=0)
