//! League table: all five auto-tuners across all three workflows and
//! both objectives at one budget.
//!
//! ```bash
//! cargo run --release --example compare_algorithms -- [m] [reps]
//! ```

use ceal::config::WorkflowId;
use ceal::coordinator::{run_campaign, Algo, Campaign};
use ceal::sim::Objective;
use ceal::util::table::{fnum, Table};

fn main() {
    let m: usize = std::env::args()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(50);
    let reps: usize = std::env::args()
        .nth(2)
        .and_then(|v| v.parse().ok())
        .unwrap_or(15);
    let algos = [Algo::Rs, Algo::Geist, Algo::Al, Algo::Alph, Algo::Ceal];
    println!("== algorithm league table: m={m}, reps={reps} (normalized best; 1.0 = pool optimum) ==");
    for objective in Objective::ALL {
        let mut t = Table::new(&["workflow", "RS", "GEIST", "AL", "ALpH", "CEAL", "winner"])
            .align_left(&[0, 6]);
        for wf in WorkflowId::ALL {
            let mut cells = vec![wf.name().to_string()];
            let mut best: Option<(f64, Algo)> = None;
            for algo in algos {
                let agg = run_campaign(algo, &Campaign::new(wf, objective, m).with_reps(reps));
                let v = agg.mean_norm_best();
                cells.push(fnum(v, 3));
                if best.map(|(b, _)| v < b).unwrap_or(true) {
                    best = Some((v, algo));
                }
            }
            cells.push(best.unwrap().1.name().to_string());
            t.row(&cells);
        }
        println!("-- objective: {}", objective.name());
        print!("{}", t.render());
    }
}
