use ceal::config::{Config, WorkflowId};
use ceal::sim::apps::*;
use ceal::sim::{Machine, WorkflowSim};
fn main() {
    let m = Machine::default();
    let v = |cfg: &[i64]| voro::profile(cfg, 16000.0*48.0, &m).t_chunk_s;
    println!("voro t1(64,16,1)={:.3} t4(64,16,4)={:.3}", v(&[64,16,1]), v(&[64,16,4]));
    let gs = |cfg: &[i64]| { let p = grayscott::profile(cfg, &m); p.n_chunks as f64 * p.t_chunk_s };
    println!("gs busy(35,35)={:.1} (66,34)={:.1} (175,13)={:.1} (525,35)={:.1}", gs(&[35,35]), gs(&[66,34]), gs(&[175,13]), gs(&[525,35]));
    let lv = WorkflowSim::new(WorkflowId::LV).with_noise(0.0);
    let e = |s: &WorkflowSim, c: &[i64]| s.expected(&Config(c.to_vec()));
    let b = e(&lv, &[430,23,1,300,88,10,4]); let x = e(&lv, &[288,18,2,400,288,18,2]);
    println!("LV exec best={:.1}s({}n {:.2}ch) expert={:.1}s({}n {:.2}ch)", b.exec_time_s, b.nodes, b.computer_time_core_h, x.exec_time_s, x.nodes, x.computer_time_core_h);
    let bc = e(&lv, &[175,35,2,400,38,29,3]); let xc = e(&lv, &[18,18,2,400,18,18,2]);
    println!("LV comp best={:.2}ch({:.0}s {}n) expert={:.2}ch({:.0}s {}n)", bc.computer_time_core_h, bc.exec_time_s, bc.nodes, xc.computer_time_core_h, xc.exec_time_s, xc.nodes);
    let hs = WorkflowSim::new(WorkflowId::HS).with_noise(0.0);
    let hb = e(&hs, &[13,17,14,4,29,19,3]); let hx = e(&hs, &[32,17,34,4,20,560,35]);
    println!("HS exec best={:.2}s({:.3}ch {}n) expert={:.2}s({:.3}ch {}n)", hb.exec_time_s, hb.computer_time_core_h, hb.nodes, hx.exec_time_s, hx.computer_time_core_h, hx.nodes);
    let hbc = e(&hs, &[5,25,35,4,3,5,3]); let hxc = e(&hs, &[8,4,32,4,20,35,35]);
    println!("HS comp best={:.3}ch({:.0}s {}n) expert={:.3}ch({:.0}s {}n)", hbc.computer_time_core_h, hbc.exec_time_s, hbc.nodes, hxc.computer_time_core_h, hxc.exec_time_s, hxc.nodes);
    let gp = WorkflowSim::new(WorkflowId::GP).with_noise(0.0);
    let gb = e(&gp, &[175,13,24,23]); let gx = e(&gp, &[525,35,525,35]);
    println!("GP exec best={:.1}s({}n) expert={:.1}s({}n)", gb.exec_time_s, gb.nodes, gx.exec_time_s, gx.nodes);
    let gbc = e(&gp, &[66,34,41,22]); let gxc = e(&gp, &[35,35,35,35]);
    println!("GP comp best={:.2}ch({:.0}s {}n) expert={:.2}ch({:.0}s {}n)", gbc.computer_time_core_h, gbc.exec_time_s, gbc.nodes, gxc.computer_time_core_h, gxc.exec_time_s, gxc.nodes);
    let f525 = e(&gp, &[525,35,128,32]);
    println!("GP fast(525,35,128,32): exec={:.1} nodes={}", f525.exec_time_s, f525.nodes);
}
