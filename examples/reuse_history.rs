//! Component-model reuse (paper §7.5): when historical component
//! measurements exist — e.g. the same LAMMPS or Gray-Scott binary was
//! tuned inside another workflow — CEAL trains its component models for
//! free and spends the whole budget on workflow runs.
//!
//! ```bash
//! cargo run --release --example reuse_history -- [m] [reps]
//! ```

use ceal::config::WorkflowId;
use ceal::coordinator::{run_campaign, Algo, Campaign};
use ceal::sim::Objective;
use ceal::util::table::{fnum, Table};

fn main() {
    let m: usize = std::env::args()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(25);
    let reps: usize = std::env::args()
        .nth(2)
        .and_then(|v| v.parse().ok())
        .unwrap_or(15);
    println!("== component-measurement reuse: m={m}, reps={reps} ==");
    println!("(500 historical isolated runs per component, free of charge)\n");
    for objective in Objective::ALL {
        let mut t = Table::new(&[
            "workflow",
            "CEAL w/o hist",
            "CEAL w/ hist",
            "hist gain",
            "ALpH w/ hist",
            "CEAL vs ALpH",
        ])
        .align_left(&[0]);
        for wf in WorkflowId::ALL {
            let no = run_campaign(Algo::Ceal, &Campaign::new(wf, objective, m).with_reps(reps));
            let with =
                run_campaign(Algo::CealHist, &Campaign::new(wf, objective, m).with_reps(reps));
            let alph =
                run_campaign(Algo::AlphHist, &Campaign::new(wf, objective, m).with_reps(reps));
            t.row(&[
                wf.name().into(),
                fnum(no.mean_norm_best(), 3),
                fnum(with.mean_norm_best(), 3),
                fnum((1.0 - with.mean_best() / no.mean_best()) * 100.0, 1) + "%",
                fnum(alph.mean_norm_best(), 3),
                fnum((1.0 - with.mean_best() / alph.mean_best()) * 100.0, 1) + "%",
            ]);
        }
        println!("-- objective: {}", objective.name());
        print!("{}", t.render());
    }
    println!(
        "paper reference (§7.5.1-2, m=25 comp time): hist gains LV 10.0% / HS 38.9% / \
         GP 4.8%; CEAL beats ALpH by LV 15.1% / HS 32.6% / GP 6.5%"
    );
}
