use ceal::config::WorkflowId;
use ceal::coordinator::historical_samples;
use ceal::metrics::recall_score;
use ceal::sim::Objective;
use ceal::surrogate::{LowFiModel, Scorer};
use ceal::tuner::ceal::gbt_params_for;
use ceal::tuner::{Pool, Problem};

fn main() {
    for id in WorkflowId::ALL {
        for obj in Objective::ALL {
            let prob = Problem::new(id, obj);
            let pool = Pool::generate(&prob, 500, 0xF14);
            for n_hist in [25usize, 500] {
                let hist = historical_samples(&prob, n_hist, 0x415);
                let nf = prob.n_component_features();
                let lf = LowFiModel::fit(&hist, &nf, obj, &gbt_params_for(n_hist));
                let scores = lf.score(&pool.feats, &Scorer::Native);
                let r: Vec<String> = [5, 10, 25]
                    .iter()
                    .map(|&n| format!("{:.0}%", recall_score(n, &scores, pool.truth()) * 100.0))
                    .collect();
                println!("{} {} hist={:<4} recall@5/10/25 = {}", id, obj, n_hist, r.join(" / "));
            }
        }
    }
}
