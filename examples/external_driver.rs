//! Embedding the tuner: an *external* driver that owns the measurement
//! loop — the ask/tell inversion the session API exists for.
//!
//! Nothing here uses `drive()` or the simulator-backed `Collector`
//! evaluator: the driver decides how each requested measurement is
//! performed (here the simulator stands in for a real batch scheduler
//! or workflow runner) and feeds the observed values back.
//!
//! Run with: `cargo run --release --example external_driver`

use ceal::config::WorkflowId;
use ceal::sim::Objective;
use ceal::surrogate::Scorer;
use ceal::tuner::{
    Ceal, CealParams, MeasurementRequest, MeasurementResult, Pool, Problem, Tuner,
};
use ceal::util::rng::Pcg32;

fn main() {
    let prob = Problem::new(WorkflowId::LV, Objective::CompTime);
    let pool = Pool::generate(&prob, 300, 7);
    let scorer = Scorer::Native;
    let tuner = Ceal::new(CealParams::no_hist());

    // ---- the 20-line ask/tell loop an embedder writes ----
    let mut rng = Pcg32::new(42, 0);
    let mut measure_rng = Pcg32::new(42, 1); // the *driver's* noise source
    let mut session = tuner.session(&prob, &pool, &scorer, 30, &mut rng);
    loop {
        let batch = session.ask();
        if batch.is_empty() {
            break; // budget spent
        }
        let results: Vec<MeasurementResult> = batch
            .requests
            .iter()
            .map(|req| {
                // launch on your infrastructure; the simulator stands in
                let value = match req {
                    MeasurementRequest::Workflow { config, .. } => {
                        prob.objective.value(&prob.sim.run(config, &mut measure_rng))
                    }
                    MeasurementRequest::Component { comp, config } => prob
                        .objective
                        .value(&prob.sim.run_component(*comp, config, &mut measure_rng)),
                };
                MeasurementResult { value }
            })
            .collect();
        session.tell(&results);
        println!(
            "[{}] told {} results (runs {}, cost {:.1})",
            session.state().phase,
            results.len(),
            session.state().workflow_runs,
            session.state().collection_cost,
        );
    }
    let out = session.finish();
    // ------------------------------------------------------

    println!(
        "tuned config {} -> true objective {:.3} (pool best {:.3})",
        pool.configs[out.best_idx],
        pool.truth_of(out.best_idx),
        pool.best_value(),
    );
}
