//! End-to-end driver (EXPERIMENTS.md §End-to-end): the full three-layer
//! system on the paper's headline workload.
//!
//! Tunes the LV workflow for both objectives with CEAL at m = 50
//! against the RS / GEIST / AL baselines, with the scoring hot path
//! running through the AOT artifacts over PJRT (L1 Pallas kernel inside
//! the L2 JAX graph, executed by this Rust binary).  Reports the
//! paper's headline quantities: tuned-vs-baseline improvement, top-1
//! recall, collection cost, and the least-number-of-uses payoff.
//!
//! ```bash
//! make artifacts && cargo run --release --example tune_lv -- [reps]
//! ```

use ceal::config::WorkflowId;
use ceal::coordinator::{run_campaign, Algo, Campaign, ScorerKind};
use ceal::sim::Objective;
use ceal::util::table::{fnum, Table};

fn main() {
    let reps: usize = std::env::args()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(20);
    let m = 50;
    println!("== CEAL end-to-end on LV: m={m}, reps={reps}, pool=2000 ==");
    println!("scoring through the PJRT artifacts (one compile per worker thread)\n");

    for objective in Objective::ALL {
        let mut table = Table::new(&[
            "algo",
            "tuned (mean)",
            "normalized",
            "top-1 recall",
            "cost",
            "payoff runs",
        ])
        .align_left(&[0]);
        let mut ceal_val = f64::NAN;
        let mut rs_val = f64::NAN;
        let mut geist_val = f64::NAN;
        for algo in [Algo::Rs, Algo::Geist, Algo::Al, Algo::Ceal] {
            // PJRT scorer on a single worker: the compiled artifacts are
            // reused across all repetitions.
            let campaign = Campaign::new(WorkflowId::LV, objective, m)
                .with_reps(reps)
                .with_scorer(ScorerKind::Pjrt)
                .with_threads(1);
            let agg = run_campaign(algo, &campaign);
            match algo {
                Algo::Ceal => ceal_val = agg.mean_best(),
                Algo::Rs => rs_val = agg.mean_best(),
                Algo::Geist => geist_val = agg.mean_best(),
                _ => {}
            }
            table.row(&[
                algo.name().into(),
                format!("{} {}", fnum(agg.mean_best(), 3), objective.unit()),
                fnum(agg.mean_norm_best(), 3),
                fnum(agg.mean_recall(1) * 100.0, 0) + "%",
                fnum(agg.mean_cost(), 1),
                agg.payoff_runs()
                    .map(|p| fnum(p, 0))
                    .unwrap_or_else(|| "never".into()),
            ]);
        }
        println!("-- objective: {}", objective.name());
        print!("{}", table.render());
        println!(
            "CEAL vs RS: {}% better; vs GEIST: {}% better  \
             (paper at m=50: 17.6%/40.8% vs RS, 12.4%/32.5% vs GEIST)\n",
            fnum((1.0 - ceal_val / rs_val) * 100.0, 1),
            fnum((1.0 - ceal_val / geist_val) * 100.0, 1),
        );
    }
}
