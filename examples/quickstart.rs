//! Quickstart: simulate an in-situ workflow, train a CEAL auto-tuner
//! with a 25-run budget, and inspect the result.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use ceal::config::WorkflowId;
use ceal::sim::Objective;
use ceal::surrogate::Scorer;
use ceal::tuner::{Ceal, CealParams, Pool, Problem, Tuner};
use ceal::util::rng::Pcg32;

fn main() {
    // A tuning problem: workflow LV (LAMMPS + Voro++), minimize
    // computer time (core-hours).
    let prob = Problem::new(WorkflowId::LV, Objective::CompTime);

    // Run the simulator once at an arbitrary configuration.
    let cfg = ceal::config::Config(vec![128, 16, 2, 200, 64, 16, 2]);
    let m = prob.sim.expected(&cfg);
    println!(
        "one run of {cfg}: {:.1} s wall-clock on {} nodes = {:.2} core-h",
        m.exec_time_s, m.nodes, m.computer_time_core_h
    );

    // The sample pool C_pool (the paper uses 2000; 400 keeps the
    // quickstart fast) and its ground truth.
    let pool = Pool::generate(&prob, 400, 42);
    println!(
        "pool of {} feasible configs; best {:.3} core-h at {}",
        pool.len(),
        pool.best_value(),
        pool.configs[pool.best_idx()]
    );

    // Score configurations through the AOT artifacts when available
    // (L1 Pallas kernel -> L2 JAX graph -> L3 PJRT runtime), falling
    // back to the exact native mirror otherwise.
    let scorer = Scorer::pjrt_or_native();
    println!("scoring backend: {}", scorer.name());

    // Auto-tune with CEAL under a 25-workflow-run budget.
    let mut rng = Pcg32::new(7, 0);
    let out = Ceal::new(CealParams::no_hist()).run(&prob, &pool, &scorer, 25, &mut rng);
    let tuned = pool.truth_of(out.best_idx);
    println!(
        "CEAL spent {} workflow runs (cost {:.1} core-h) and proposes {}",
        out.workflow_runs, out.collection_cost, pool.configs[out.best_idx]
    );
    println!(
        "tuned {:.3} core-h vs pool best {:.3} (normalized {:.3})",
        tuned,
        pool.best_value(),
        tuned / pool.best_value()
    );
}
